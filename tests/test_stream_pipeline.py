"""Pipelined ingest→serve path: incremental presence, async windows, QoS.

The serving-path contracts this file pins:

* the device-resident ELL presence plane is maintained by scattering only
  the slots a ``SlideDiff`` flipped — ``touched`` counters are pinned the
  way collective counts are HLO-pinned: they track the diff size, never the
  capacity, and the plane stays bit-for-bit equal to a full rebuild;
* the plane is invalidated exactly when the pack changes (the freed-slot
  invariant's presence twin): capacity growth / new registrations rebuild,
  20 no-repack slides do not;
* ``QueryBatcher`` pipelined serving (``advance_window_async``) is
  bit-for-bit equal to the synchronous path across semirings, engines and
  deployments, including back-to-back in-flight windows and a mid-stream
  capacity repack;
* eviction runs on the serving path itself: a watcher idle past TTL is
  dropped by ``advance_window`` ALONE (no ``watch``/``sweep`` call), at a
  frozen lane-capacity class; divergence fires at exactly window distance;
* lane-aware QoS: a pathological watcher is quarantined into its own
  single-lane group, still served bit-for-bit, TTL-expired at half life and
  preferred for LRU eviction;
* ``SnapshotLog`` weight events: bisect lookup == linear scan, compaction
  keeps O(live) events without changing reachable lookups;
* ``occupancy_spread`` degenerate fixtures and the BENCH json schema.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.api import EvolvingQuery, StreamingQuery
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
from repro.graph.stream import SnapshotLog, WindowView
from repro.kernels.vrelax.ops import (
    EllPresenceCache,
    presence_word_pattern,
)
from repro.serving.scheduler import QueryBatcher

V = 48
WINDOW = 3
NO_DELTA = ((), (), (), (), ())


def make_stream(seed: int, *, num_snapshots: int = WINDOW + 3, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def feed(log, base, deltas, upto: int):
    log.append_snapshot(*base)
    for d in deltas[: upto - 1]:
        log.append_snapshot(*d)
    return log


def tip_ref(log, query: str, source: int) -> np.ndarray:
    """Fresh ground truth on the TIP window (a fresh view defaults to the
    FIRST window, so references must anchor ``start`` explicitly)."""
    view = WindowView(log, size=WINDOW, start=log.num_snapshots - WINDOW)
    return EvolvingQuery(view.materialize(), query, source).evaluate("cqrs")


# ===================================================================
# EllPresenceCache unit contracts
# ===================================================================
def test_presence_word_pattern_widths():
    np.testing.assert_array_equal(presence_word_pattern(), [1])
    np.testing.assert_array_equal(presence_word_pattern(1), [1])
    np.testing.assert_array_equal(presence_word_pattern(8), [0xFF])
    np.testing.assert_array_equal(presence_word_pattern(32), [0xFFFFFFFF])
    np.testing.assert_array_equal(
        presence_word_pattern(40), [0xFFFFFFFF, 0xFF]
    )


def test_presence_cache_incremental_matches_rebuild():
    """Scattered updates == full rebuilds bit-for-bit; touched == flips."""
    rng = np.random.default_rng(3)
    eid = np.array([[0, 1, 2, -1], [3, 4, 5, 6], [-1, 7, 8, 9]])
    n_slots = 10
    inc = EllPresenceCache()
    legacy = EllPresenceCache()
    legacy.incremental = False
    mask = rng.random(n_slots) < 0.5
    flips = [np.array([0]), np.array([]), np.array([4, 7, 9]),
             np.arange(n_slots), np.array([2])]
    for q in (None, 8, 40):
        for step, f in enumerate(flips):
            if len(f):
                mask[f.astype(int)] = ~mask[f.astype(int)]
            got = np.asarray(inc.update(("k", q), mask, eid, num_queries=q))
            want = np.asarray(
                legacy.update(("k", q), mask, eid, num_queries=q)
            )
            np.testing.assert_array_equal(
                got, want, err_msg=f"q={q} step={step}"
            )
    # one rebuild per (key, Q) layout; every other update was a scatter
    assert inc.rebuilds == 3
    assert legacy.rebuilds == 3 * len(flips)
    # touched pins the flip sizes (4 scatter updates per layout epoch)
    assert inc.touched == [0, 3, 10, 1] * 3
    # a key change (repack) invalidates even with an identical mask
    before = inc.rebuilds
    inc.update(("k2", 8), mask, eid, num_queries=8)
    assert inc.rebuilds == before + 1


def test_presence_cache_absent_slots_do_not_scatter():
    """Universe ids with no packed slot are dropped from the diff (the
    single-host pack covers only QRS-kept edges, so gaps are routine)."""
    eid = np.array([[0, 1, -1], [2, 4, -1]])  # id 3 has no packed slot
    inc = EllPresenceCache()
    mask = np.array([True, False, True, True, False])
    inc.update("k", mask, eid)
    mask = mask.copy()
    mask[[1, 3]] = [True, False]  # id 3 flips but cannot scatter
    inc.update("k", mask, eid)
    assert inc.touched == [1]
    ref = EllPresenceCache()
    ref.incremental = False
    np.testing.assert_array_equal(
        np.asarray(inc.update("k", mask, eid)),
        np.asarray(ref.update("k", mask, eid)),
    )


# ===================================================================
# Pinned no-repack maintenance: touched tracks the diff, not capacity
# ===================================================================
def _grouped_edges():
    """40 distinct edges in 5 delete/re-add rotation groups of 8."""
    idx = np.arange(40)
    src = idx % V
    dst = (idx + 5) % V
    w = (1.0 + (idx % 16) / 16.0).astype(np.float32)
    groups = [np.flatnonzero(idx % 5 == g) for g in range(5)]
    return src, dst, w, groups


def _rotation_delta(k: int, src, dst, w, groups):
    """Slide ``k``: delete group ``k%5``; re-add group ``(k-2)%5`` at its
    ORIGINAL weights (registered edges, unchanged extrema → no repack)."""
    g_del = groups[k % 5]
    if k < 2:
        return ((), (), (), src[g_del], dst[g_del])
    g_add = groups[(k - 2) % 5]
    return (src[g_add], dst[g_add], w[g_add], src[g_del], dst[g_del])


_TOUCHED_BY_CAP: dict = {}


@pytest.mark.parametrize("capacity", [64, 256])
def test_presence_touched_pinned_over_20_slides(capacity):
    """20 slides, zero repacks: ONE rebuild, every scatter ≤ diff-sized,
    and the counter stream is identical across capacity classes."""
    src, dst, w, groups = _grouped_edges()
    slog = ShardedSnapshotLog(V, 1, capacity=capacity)
    slog.append_snapshot(src, dst, w)
    for _ in range(WINDOW - 1):
        slog.append_snapshot(*NO_DELTA)
    ref_log = feed(SnapshotLog(V, capacity=capacity), (src, dst, w),
                   [NO_DELTA] * (WINDOW - 1), WINDOW)
    view = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0, method="cqrs_ell")
    sq.results  # prime
    key0 = slog.state_key()
    for k in range(20):
        d = _rotation_delta(k, src, dst, w, groups)
        got = sq.advance(d)
        ref_log.append_snapshot(*d)
        if k in (0, 9, 19):
            np.testing.assert_array_equal(
                got, tip_ref(ref_log, "sssp", 0),
                err_msg=f"slide {k} (capacity {capacity})",
            )
    assert slog.state_key() == key0, "rotation deltas must not repack"
    stats = sq._ell_cache.presence_stats()
    assert stats["rebuilds"] == 1, "no-repack slides must never rebuild"
    # every scatter is bounded by the universe (40 edges), NOT the capacity
    assert stats["touched"] and max(stats["touched"]) <= 40
    # the counter stream is capacity-independent: pin it for cross-run
    # comparison via a module-level record (both parametrizations fill it)
    _TOUCHED_BY_CAP[capacity] = stats["touched"]
    if len(_TOUCHED_BY_CAP) == 2:
        a, b = (_TOUCHED_BY_CAP[c] for c in sorted(_TOUCHED_BY_CAP))
        assert a == b, "touched counters must not depend on capacity class"


def test_presence_plane_invalidated_on_repack():
    """Registering NEW edges repacks the ELL → the plane must rebuild."""
    src, dst, w, groups = _grouped_edges()
    slog = ShardedSnapshotLog(V, 1, capacity=64)
    slog.append_snapshot(src, dst, w)
    for _ in range(WINDOW - 1):
        slog.append_snapshot(*NO_DELTA)
    ref_log = feed(SnapshotLog(V, capacity=64), (src, dst, w),
                   [NO_DELTA] * (WINDOW - 1), WINDOW)
    view = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0, method="cqrs_ell")
    sq.results
    sq.advance(_rotation_delta(0, src, dst, w, groups))
    assert sq._ell_cache.presence_stats()["rebuilds"] == 1
    # brand-new edges: num_edges moves → state_key moves → repack
    fresh = (np.array([45, 46]), np.array([3, 4]),
             np.array([1.5, 2.5], np.float32), (), ())
    got = sq.advance(fresh)
    ref_log.append_snapshot(*_rotation_delta(0, src, dst, w, groups))
    ref_log.append_snapshot(*fresh)
    np.testing.assert_array_equal(got, tip_ref(ref_log, "sssp", 0))
    assert sq._ell_cache.presence_stats()["rebuilds"] == 2


# ===================================================================
# Pipelined == synchronous serving (the tentpole equivalence)
# ===================================================================
def _dual_batchers(seed, query, method, sharded, slides=3, sources=(0, 7)):
    """Two identically-fed deployments: synchronous vs pipelined batcher.

    Yields per-slide result dicts from both paths; the caller asserts.
    """
    base, deltas = make_stream(seed, num_snapshots=WINDOW + slides + 1)

    def build():
        if sharded:
            log = ShardedSnapshotLog(V, 1, capacity=64)
        else:
            log = SnapshotLog(V, capacity=512)
        feed(log, base, deltas, WINDOW)
        mk = ShardedWindowView if sharded else WindowView
        return log, mk(log, size=WINDOW)

    log_s, view_s = build()
    log_p, view_p = build()
    qb_s = QueryBatcher(method=method)
    qb_p = QueryBatcher(method=method, pipelined=True)
    for x in sources:
        qb_s.watch(view_s, query, x, method=method)
        qb_p.watch(view_p, query, x, method=method)
    out = []
    for d in deltas[WINDOW - 1 :]:
        out.append((qb_s.advance_window(view_s, d),
                    qb_p.advance_window(view_p, d)))
    qb_p.close()
    return out, (log_s, log_p)


@pytest.mark.parametrize("query", ["sssp", "sswp", "ssnp"])
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_pipelined_matches_synchronous(query, method):
    out, _ = _dual_batchers(seed=5, query=query, method=method, sharded=False)
    for k, (sync, pipe) in enumerate(out):
        assert set(sync) == set(pipe)
        for key in sync:
            np.testing.assert_array_equal(
                sync[key], pipe[key],
                err_msg=f"{query}/{method} slide {k} lane {key}",
            )


def test_pipelined_matches_synchronous_sharded():
    out, _ = _dual_batchers(
        seed=6, query="sssp", method="cqrs_ell", sharded=True
    )
    for k, (sync, pipe) in enumerate(out):
        assert set(sync) == set(pipe)
        for key in sync:
            np.testing.assert_array_equal(
                sync[key], pipe[key],
                err_msg=f"sharded slide {k} lane {key}",
            )


def test_backtoback_async_windows_strictly_ordered():
    """Queue THREE windows before materializing any; results must match
    per-window tip references (ingest k+1 must not overtake serve k)."""
    slides = 3
    base, deltas = make_stream(seed=11, num_snapshots=WINDOW + slides + 1)
    slog = ShardedSnapshotLog(V, 1, capacity=64)
    feed(slog, base, deltas, WINDOW)
    ref_log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    view = ShardedWindowView(slog, size=WINDOW)
    qb = QueryBatcher(method="cqrs_ell", pipelined=True)
    for x in (0, 7):
        qb.watch(view, "sssp", x, method="cqrs_ell")
    pendings = [qb.advance_window_async(view, d)
                for d in deltas[WINDOW - 1 :]]
    refs = []
    for d in deltas[WINDOW - 1 :]:
        ref_log.append_snapshot(*d)
        refs.append({("sssp", x): tip_ref(ref_log, "sssp", x)
                     for x in (0, 7)})
    for k, (p, ref) in enumerate(zip(pendings, refs)):
        got = p.result()
        assert p.done()
        assert len(p.group_futures()) == 1
        assert set(got) == set(ref)
        for key in ref:
            np.testing.assert_array_equal(
                got[key], ref[key], err_msg=f"window {k} lane {key}"
            )
    qb.close()


def test_pipelined_capacity_growth_mid_stream(monkeypatch):
    """A slide that GROWS the universe capacity mid-pipeline (generation
    bump → repack → presence invalidation) stays bit-for-bit."""
    from repro.graph import stream as stream_mod

    monkeypatch.setattr(stream_mod, "STREAM_ALIGN", 8)
    base, deltas = make_stream(seed=21, num_snapshots=WINDOW + 4)
    probe = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    tight = probe.num_edges  # tip capacity: first registration grows

    def build():
        slog = ShardedSnapshotLog(V, 1, capacity=tight)
        return feed(slog, base, deltas, WINDOW)

    log_s, log_p = build(), build()
    view_s = ShardedWindowView(log_s, size=WINDOW)
    view_p = ShardedWindowView(log_p, size=WINDOW)
    qb_s = QueryBatcher(method="cqrs_ell")
    qb_p = QueryBatcher(method="cqrs_ell", pipelined=True)
    for x in (0, 7):
        qb_s.watch(view_s, "sssp", x, method="cqrs_ell")
        qb_p.watch(view_p, "sssp", x, method="cqrs_ell")
    gen0 = log_p.state_key()
    for k, d in enumerate(deltas[WINDOW - 1 :]):
        sync = qb_s.advance_window(view_s, d)
        pipe = qb_p.advance_window(view_p, d)
        for key in sync:
            np.testing.assert_array_equal(
                sync[key], pipe[key], err_msg=f"slide {k} lane {key}"
            )
    assert log_p.state_key() != gen0, "stream must have forced a repack"
    (grp,) = [b for b in qb_p._batches.values() if b.view is view_p]
    assert grp._ell_cache.presence_stats()["rebuilds"] >= 2, \
        "the repack must have invalidated the presence plane"
    qb_p.close()


# ===================================================================
# Eviction on the serving path
# ===================================================================
def test_ttl_eviction_by_advance_window_alone():
    """An idle-past-TTL watcher is dropped by ``advance_window`` ALONE —
    no ``watch``/``sweep`` call — at a frozen lane-capacity class."""
    now = [0.0]
    base, deltas = make_stream(seed=31, num_snapshots=WINDOW + 4)
    log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher(stream_ttl=10.0, clock=lambda: now[0])
    qb.watch(view, "sssp", 0)
    h7 = qb.watch(view, "sssp", 7)
    batch = h7.batch
    cap0 = batch.lane_capacity
    out = qb.advance_window(view, deltas[WINDOW - 1])
    assert set(out) == {("sssp", 0), ("sssp", 7)}
    now[0] = 6.0
    qb.watch(view, "sssp", 0)  # client 0 is alive; client 7 went silent
    now[0] = 12.0  # 7 idle for 12s > TTL; 0 idle for 6s
    out = qb.advance_window(view, deltas[WINDOW])
    assert set(out) == {("sssp", 0)}, "advance_window alone must evict"
    assert batch.sources == [0]
    assert batch.lane_capacity == cap0, "lane Q-class must stay frozen"
    assert qb.cache_info().evictions == 1
    np.testing.assert_array_equal(out[("sssp", 0)], tip_ref(log, "sssp", 0))
    # the surviving watcher expires too once idle past TTL: explicit sweep
    now[0] = 30.0
    assert qb.sweep() == 1
    assert qb.cache_info().currsize == 0 and not qb._batches


def test_divergence_eviction_at_exactly_window_distance():
    """The log sliding a FULL window past a view makes its warm state
    useless — the predicate must fire at exactly-window distance, not
    before (windows are disjoint only from ``size`` onward)."""
    base, deltas = make_stream(seed=33, num_snapshots=2 * WINDOW + 2)
    log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher()
    qb.watch(view, "sssp", 0)
    for d in deltas[WINDOW - 1 : 2 * WINDOW - 2]:  # distance → WINDOW-1
        log.append_snapshot(*d)
    assert log.num_snapshots - (view.start + view.size) == WINDOW - 1
    assert qb.sweep() == 0, "one-short of a window is NOT divergent"
    log.append_snapshot(*deltas[2 * WINDOW - 2])  # distance → WINDOW
    assert qb.sweep() == 1, "exactly a window past must evict"
    assert qb.cache_info().currsize == 0


# ===================================================================
# Lane-aware QoS: quarantine
# ===================================================================
def _quarantine_batcher(clock=None, **kw):
    base, deltas = make_stream(seed=41, num_snapshots=WINDOW + 6)
    log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher(quarantine_factor=0.01, method="cqrs",
                      **({"clock": clock} if clock else {}), **kw)
    qb.watch(view, "sssp", 0)
    qb.watch(view, "sssp", 7)
    return qb, view, log, deltas[WINDOW - 1 :]


def test_quarantine_isolates_pathological_lane():
    """With a tiny factor one lane lands in its own group; serving stays
    bit-for-bit and covers BOTH watchers from the split groups."""
    qb, view, log, pending = _quarantine_batcher()
    served = [qb.advance_window(view, d) for d in pending[:3]]
    assert len(qb.quarantined()) == 1
    assert len(qb._batches) == 2, "quarantined lane must get its own group"
    solo_sources = sorted(
        s for b in qb._batches.values() for s in b.sources
    )
    assert solo_sources == [0, 7], "no lane may be lost by the split"
    for k, out in enumerate(served):
        assert set(out) == {("sssp", 0), ("sssp", 7)}
    for x in (0, 7):
        np.testing.assert_array_equal(
            served[-1][("sssp", x)], tip_ref(log, "sssp", x),
            err_msg=f"post-quarantine serving diverged (source {x})",
        )
    assert qb.cache_info().currsize == 2


def test_quarantined_lane_is_preferred_lru_victim():
    qb, view, log, pending = _quarantine_batcher(stream_capacity=2)
    qb.advance_window(view, pending[0])
    qb.advance_window(view, pending[1])
    (bad,) = qb.quarantined()
    qb.watch(view, "sssp", 13)  # overflow: capacity 2, third watcher
    assert qb.quarantined() == [], "quarantined lane must be evicted first"
    keys = {(e.sq.semiring.name, e.sq.source)
            for e in qb._streams.values()}
    assert bad not in keys and ("sssp", 13) in keys


def test_quarantined_lane_expires_at_half_ttl():
    now = [0.0]
    qb, view, log, pending = _quarantine_batcher(
        clock=lambda: now[0], stream_ttl=10.0
    )
    qb.advance_window(view, pending[0])
    qb.advance_window(view, pending[1])
    assert len(qb.quarantined()) == 1
    now[0] = 6.0  # past TTL/2=5 for the quarantined lane, inside TTL for
    assert qb.sweep(exempt_view=view) == 1  # the healthy one
    assert qb.quarantined() == []
    assert qb.cache_info().currsize == 1


# ===================================================================
# Weight events: bisect == linear scan; compaction keeps O(live)
# ===================================================================
def _weight_at_linear(ev, t):
    w = ev[0][1]
    for tt, ww in ev[1:]:
        if tt <= t:
            w = ww
        else:
            break
    return w


def test_weight_at_bisect_matches_linear_reference():
    log = SnapshotLog(V, capacity=64)
    log.append_snapshot([0, 2, 4], [1, 3, 5], [1.0, 1.0, 9.0])
    log.append_snapshot([0, 2], [1, 3], [3.0, 7.0])  # both re-assigned
    log.append_snapshot([0], [1], [2.0])
    log.append_snapshot(*NO_DELTA)
    log.append_snapshot([0], [1], [5.0])
    j01 = int(np.flatnonzero((log.src[: log.num_edges] == 0)
                             & (log.dst[: log.num_edges] == 1))[0])
    j23 = int(np.flatnonzero((log.src[: log.num_edges] == 2)
                             & (log.dst[: log.num_edges] == 3))[0])
    for j in (j01, j23):
        ev = list(log._wevents[j])
        for t in range(log.num_snapshots):
            assert log.weight_at(j, t) == _weight_at_linear(ev, t), \
                f"edge {j} at t={t}"
    # an edge with no events resolves to its (only) tip weight
    stable = next(j for j in range(log.num_edges) if j not in log._wevents)
    assert log.weight_at(stable, 0) == log.weight_tip[stable]


def test_weight_event_compaction_keeps_live_events_only():
    log = SnapshotLog(V, capacity=64)
    log.append_snapshot([0, 2], [1, 3], [1.0, 1.0])
    log.append_snapshot([0, 2], [1, 3], [3.0, 7.0])
    log.append_snapshot([0], [1], [2.0])
    log.append_snapshot(*NO_DELTA)
    log.append_snapshot([0], [1], [5.0])
    j01 = int(np.flatnonzero((log.src[: log.num_edges] == 0)
                             & (log.dst[: log.num_edges] == 1))[0])
    j23 = int(np.flatnonzero((log.src[: log.num_edges] == 2)
                             & (log.dst[: log.num_edges] == 3))[0])
    start = log.num_snapshots - 2  # = 3: snapshots 0..2 become unreachable
    view = WindowView(log, size=2, start=start)
    want = {j: [log.weight_at(j, t)
                for t in range(start, log.num_snapshots)]
            for j in (j01, j23)}
    assert log.retire_history() == start
    # (0,1) still has a live event (t=4): folded seed + live entry only
    assert log._wevents[j01] == [(-1, np.float32(2.0)),
                                 (4, np.float32(5.0))]
    # (2,3)'s events ALL folded: entry dropped, extrema pinned to the tip
    assert j23 not in log._wevents
    assert log.weight_min[j23] == log.weight_max[j23] == np.float32(7.0)
    for j in (j01, j23):  # reachable lookups are bit-for-bit unchanged
        got = [log.weight_at(j, t) for t in range(start, log.num_snapshots)]
        assert got == want[j]


def test_weight_events_stay_bounded_under_sliding_view():
    """30 alternating re-assignments, pruned as a window slides over them:
    the event list must stay O(live window), not O(history)."""
    log = SnapshotLog(V, capacity=64)
    log.append_snapshot([0], [1], [1.0])
    log.append_snapshot([0], [1], [2.0])
    view = WindowView(log, size=2, start=0)
    for t in range(2, 31):
        log.append_snapshot([0], [1], [float(1 + t % 2)])
        view.slide_to_tip()
        view.prune_history(view.history_end)
    (j,) = log.multi_weight_ids().tolist()
    assert len(log._wevents[j]) <= 4, \
        "event list must not grow with log lifetime"
    assert log.retired_upto >= log.num_snapshots - 3


# ===================================================================
# occupancy_spread degenerate fixtures
# ===================================================================
def test_occupancy_spread_empty_universe_is_even():
    slog = ShardedSnapshotLog(V, 4, capacity=16)
    assert slog.occupancy_spread() == 1.0


def test_occupancy_spread_single_populated_shard_is_shard_count():
    slog = ShardedSnapshotLog(V, 4, capacity=16)
    # naive dst-range owners: every dst < V/4 lands on shard 0
    slog.append_snapshot([0, 1, 2, 3], [1, 2, 3, 4], [1.0, 1.0, 1.0, 1.0])
    assert slog.occupancy_spread() == 4.0


# ===================================================================
# BENCH json artifact schema
# ===================================================================
def test_bench_json_payload_well_formed():
    from repro.utils.benchjson import (
        SCHEMA_VERSION, make_payload, validate_bench_json,
    )

    rows = [("evolving-stream-latency/sssp/pipelined", 1234.5, "p50_ms=1.2")]
    lat = [{
        "mode": "pipelined", "query": "sssp", "window": 64, "q": 8,
        "per_slide_ms": [1.5, 2.5], "p50_ms": 2.0, "p99_ms": 2.5,
        "touched_slots": [16, 8], "occupancy_spread": 1.0,
    }]
    payload = make_payload(rows, mode="fast",
                           meta={"argv": ["--fast"]}, latency=lat)
    assert validate_bench_json(payload) is payload
    assert payload["schema_version"] == SCHEMA_VERSION
    # round-trips through json unchanged
    import json as _json

    assert validate_bench_json(_json.loads(_json.dumps(payload)))
    # no latency section is legal (non-latency runs)
    assert validate_bench_json(make_payload(rows, mode="full"))


@pytest.mark.parametrize("mutate", [
    lambda p: p.__setitem__("schema_version", 99),
    lambda p: p.__setitem__("mode", "medium"),
    lambda p: p["rows"][0].pop("derived"),
    lambda p: p["rows"][0].__setitem__("us_per_call", "fast"),
    lambda p: p["latency"][0].pop("p99_ms"),
    lambda p: p["latency"][0].__setitem__("extra", 1),
    lambda p: p["latency"][0].__setitem__("mode", "async"),
    lambda p: p["latency"][0].__setitem__("touched_slots", [1.5]),
    lambda p: p["latency"][0].__setitem__("per_slide_ms", [True]),
    lambda p: p["latency"][0].__setitem__("window", 8.5),
])
def test_bench_json_rejects_malformed(mutate):
    from repro.utils.benchjson import make_payload, validate_bench_json

    payload = make_payload(
        [("a/b", 1.0, "")], mode="fast",
        latency=[{
            "mode": "synchronous", "query": "sssp", "window": 8, "q": 8,
            "per_slide_ms": [1.0], "p50_ms": 1.0, "p99_ms": 1.0,
            "touched_slots": [4], "occupancy_spread": 1.0,
        }],
    )
    mutate(payload)
    with pytest.raises(ValueError):
        validate_bench_json(payload)

"""Elastic online resharding: layout epochs + live shard-state migration.

Tier-1 coverage of the resharding tentpole on the lone CPU device:

* layout-epoch derivations (``rebalance``/``resize``) and the position-space
  ``MigrationPlan`` permutation;
* mid-stream ``reshard()`` of a live SPMD query — bit-for-bit equal to a
  never-resharded run with ZERO fixpoint re-solves (the hash assignment's
  local-id map is a nontrivial vertex permutation even on one shard, so the
  warm-value permute is genuinely exercised in-process; the 8-device
  grow/shrink variant lives in ``_stream_shard_checks.py::check_reshard``);
* host-level log/view resharding across shard counts (no mesh needed);
* the serving-path trigger (``ReshardPolicy``/``plan_reshard``) through
  ``QueryBatcher`` and ``ServeSupervisor``, including occupancy-spread
  recovery on a hub-drift stream;
* reshard → checkpoint → restore roundtrips, the delta-encoded checkpoint
  payload, the non-blocking background checkpoint job, and the observed ELL
  class ladder checkpointed into the warm-start grid.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core.api import EvolvingQuery, StreamingQuery, StreamingQueryBatch
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.shardlog import (
    MigrationPlan,
    ShardedSnapshotLog,
    ShardedWindowView,
    degree_histogram,
    migration_plan,
)
from repro.graph.stream import SnapshotLog, WindowView
from repro.serving.scheduler import QueryBatcher, ReshardPolicy, plan_reshard

V = 48
WINDOW = 3


def make_stream(seed: int, *, num_snapshots: int = 9, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def hash_slog(base, deltas, *, n_prime: int = WINDOW, seed: int = 0):
    """1-shard hash-assigned log primed to ``n_prime`` snapshots.

    Hash local ids are a nontrivial permutation of the vertex space, so the
    position machinery (and a later rebalance to identity-local ``balanced``
    ranges) moves real state even on one shard.
    """
    slog = ShardedSnapshotLog(V, 1, capacity=64, assignment="hash", seed=seed)
    slog.append_snapshot(*base)
    for d in deltas[: n_prime - 1]:
        slog.append_snapshot(*d)
    return slog, deltas[n_prime - 1:]


def hub_drift_stream(slides: int = 24, *, per_slide: int = 16, width: int = 6,
                     seed: int = 0):
    """Adds-only stream whose in-edge mass drifts across the vertex space.

    Each slide lands ``per_slide`` edges on a ``width``-wide hub region whose
    center sweeps 0 → V.  A layout balanced for the early hubs ends up owning
    almost none of the late mass — the workload online resharding exists for.
    """
    rng = np.random.default_rng(seed)
    base_dst = rng.integers(0, width, size=per_slide)
    base_src = rng.integers(0, V, size=per_slide)
    base = (base_src, base_dst, np.ones(per_slide, np.float32))
    deltas = []
    for t in range(1, slides):
        center = (t * V) // slides
        dst = (center + rng.integers(0, width, size=per_slide)) % V
        src = rng.integers(0, V, size=per_slide)
        w = (1.0 + rng.integers(0, 8, size=per_slide) / 8.0).astype(np.float32)
        deltas.append((src, dst, w, (), ()))
    return base, deltas


# ===================================================== layout-epoch mechanics
def test_layout_epochs_and_migration_plan():
    base, deltas = make_stream(seed=0)
    slog = ShardedSnapshotLog.from_stream(base, deltas, V, 4, capacity=64)
    old = slog.assignment
    assert old.epoch == 0
    hist = slog.live_degree_histogram()

    new = old.rebalance(hist)
    assert new.epoch == old.epoch + 1 and new.n_shards == old.n_shards
    grown = old.resize(6, hist)
    assert grown.epoch == old.epoch + 1 and grown.n_shards == 6
    shrunk = new.resize(2)
    assert shrunk.epoch == new.epoch + 1 and shrunk.n_shards == 2
    with pytest.raises(ValueError):
        old.resize(0)

    # the plan routes every vertex's old position to its new one
    plan = migration_plan(old, grown)
    assert isinstance(plan, MigrationPlan)
    vals = np.full(old.state_len, -7.0, np.float32)
    vals[old.positions] = np.arange(V, dtype=np.float32)
    out = plan.permute(vals, np.float32(-7.0))
    assert out.shape == (grown.state_len,)
    np.testing.assert_array_equal(out[grown.positions],
                                  np.arange(V, dtype=np.float32))
    # padding slots carry the fill identity
    mask = np.ones(grown.state_len, bool)
    mask[grown.positions] = False
    assert (out[mask] == -7.0).all()
    assert 0 < plan.moved <= V
    assert plan.bytes_moved(vals) == plan.moved * vals.itemsize


# ============================================== live SPMD migration (1 shard)
@pytest.mark.parametrize("query,source", [("sssp", 0), ("sswp", 5), ("bfs", 7)])
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_midstream_reshard_bit_for_bit(query, source, method):
    """A live query resharded mid-stream (hash → balanced layout) serves
    every later slide bit-for-bit equal to a never-resharded run, without
    re-solving a single fixpoint (supersteps frozen; exactly the two parent
    forest recomputes are launched)."""
    base, deltas = make_stream(seed=3)
    rlog, pending = hash_slog(base, deltas)
    ref_sq = StreamingQuery(
        ShardedWindowView(rlog, size=WINDOW), query, source, method=method
    )
    ref = [np.asarray(ref_sq.results).copy()]
    for d in pending:
        ref_sq.advance(d)
        ref.append(np.asarray(ref_sq.results).copy())

    slog, _ = hash_slog(base, deltas)
    sq = StreamingQuery(
        ShardedWindowView(slog, size=WINDOW), query, source, method=method
    )
    sq.results
    sq.advance(pending[0])
    sq.advance(pending[1])
    pre_ss, pre_la = sq._bounds.supersteps, sq._bounds.launches
    report = sq.reshard()  # default: rebalance on the live histogram
    assert report["epoch"] == 1 and slog.assignment.epoch == 1
    assert report["n_shards"] == 1
    assert report["moved_positions"] > 0  # hash → balanced really permutes
    assert report["bytes_moved"] > 0 and report["seconds"] >= 0.0
    assert sq._bounds.supersteps == pre_ss, "migration re-solved a fixpoint"
    assert sq._bounds.launches == pre_la + 2
    np.testing.assert_array_equal(np.asarray(sq.results), ref[2])
    for j, d in enumerate(pending[2:], start=2):
        sq.advance(d)
        np.testing.assert_array_equal(
            np.asarray(sq.results), ref[j + 1],
            err_msg=f"{query}/{method} slide {j} after migration",
        )


def test_midstream_batch_reshard_bit_for_bit():
    """Q-folded groups migrate as one unit: warm lane values permute through
    the shared plan (padding lanes ride along) and stay bit-for-bit."""
    base, deltas = make_stream(seed=4)
    for method in ("cqrs", "cqrs_ell"):
        rlog, pending = hash_slog(base, deltas)
        ref_sq = StreamingQueryBatch(
            ShardedWindowView(rlog, size=WINDOW), "sssp", [0, 5, 9],
            method=method,
        )
        ref = [np.asarray(ref_sq.results).copy()]
        for d in pending:
            ref_sq.advance(d)
            ref.append(np.asarray(ref_sq.results).copy())

        slog, _ = hash_slog(base, deltas)
        sq = StreamingQueryBatch(
            ShardedWindowView(slog, size=WINDOW), "sssp", [0, 5, 9],
            method=method,
        )
        sq.results
        sq.advance(pending[0])
        pre_ss, pre_la = sq._bounds.supersteps, sq._bounds.launches
        sq.reshard()
        assert sq._bounds.supersteps == pre_ss
        assert sq._bounds.launches == pre_la + 2
        np.testing.assert_array_equal(np.asarray(sq.results), ref[1])
        for j, d in enumerate(pending[1:], start=1):
            sq.advance(d)
            np.testing.assert_array_equal(
                np.asarray(sq.results), ref[j + 1],
                err_msg=f"batch/{method} slide {j} after migration",
            )


def test_reshard_requires_caught_up_query():
    base, deltas = make_stream(seed=5)
    slog, pending = hash_slog(base, deltas)
    sq = StreamingQuery(ShardedWindowView(slog, size=WINDOW), "sssp", 0)
    sq.results
    slog.append_snapshot(*pending[0])
    with pytest.raises(RuntimeError, match="caught-up"):
        sq.reshard()


def test_view_reshard_is_idempotent_for_siblings():
    """Several queries sharing one view each call reshard with the same
    target; only the first migrates the log."""
    base, deltas = make_stream(seed=6)
    slog, _ = hash_slog(base, deltas)
    sview = ShardedWindowView(slog, size=WINDOW)
    target = slog.assignment.rebalance(slog.live_degree_histogram())
    installed = sview.reshard(target)
    assert slog.assignment is installed
    again = sview.reshard(installed)
    assert again is installed and slog.assignment.epoch == installed.epoch


# ============================================ host-level resize (no mesh)
def test_host_log_resize_grow_and_shrink():
    """``ShardedSnapshotLog.reshard`` across shard counts: the re-routed log
    materializes identically to a single-host log on every remaining slide,
    snapshot indices and the retirement watermark survive, and epochs only
    move forward."""
    base, deltas = make_stream(seed=7)
    log = SnapshotLog(V, capacity=512)
    slog = ShardedSnapshotLog(V, 4, capacity=64)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    pending = deltas[WINDOW - 1:]

    def serve(d):
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
        view.slide()
        sview.slide()
        ref = EvolvingQuery(view.materialize(), "sssp", 0).evaluate("cqrs")
        got = EvolvingQuery(sview.materialize(), "sssp", 0).evaluate("cqrs")
        np.testing.assert_array_equal(got, ref)

    serve(pending[0])
    sview.prune_history(sview.history_end)  # sets a nonzero watermark
    watermark = max(sh.retired_upto for sh in slog.shards)
    assert watermark > 0

    hist = slog.live_degree_histogram()
    for n_to in (2, 6):  # shrink, then grow past the original count
        before = slog.assignment.epoch
        installed = sview.reshard(slog.assignment.resize(n_to, hist))
        assert slog.n_shards == n_to == installed.n_shards
        assert installed.epoch == before + 1
        assert slog.num_snapshots == log.num_snapshots
        assert max(sh.retired_upto for sh in slog.shards) == watermark
        # every stored edge sits on the shard the new layout names
        owner = slog.assignment.owner
        for s, sh in enumerate(slog.shards):
            n = sh.num_edges
            assert n == 0 or (owner[sh.dst[:n]] == s).all()
        serve(pending[1])
        pending = pending[1:]
    for d in pending[1:]:
        serve(d)


# ================================================== policy trigger + serving
def test_plan_reshard_policy_gates():
    base, deltas = make_stream(seed=8)
    slog = ShardedSnapshotLog.from_stream(base, deltas, V, 4, capacity=64)

    pol = ReshardPolicy(spread_threshold=0.0, min_slides=8)
    assert plan_reshard(slog, pol, slides_since=3) is None  # rate limit
    got = plan_reshard(slog, pol, slides_since=8)
    assert got is not None and got.epoch == slog.assignment.epoch + 1

    # spread under threshold, no growth, no resize target → keep the layout
    calm = ReshardPolicy(spread_threshold=1e9, on_capacity_growth=False)
    assert plan_reshard(slog, calm, capacity_grew=True) is None

    # capacity growth is a trigger on its own
    growth = ReshardPolicy(spread_threshold=1e9, on_capacity_growth=True)
    assert plan_reshard(slog, growth, capacity_grew=True) is not None

    # an explicit shard-count target always wins
    resize = ReshardPolicy(spread_threshold=1e9, n_shards=2,
                           on_capacity_growth=False)
    got = plan_reshard(slog, resize)
    assert got is not None and got.n_shards == 2

    # a derived layout identical to the current one is skipped entirely
    slog.reshard(slog.assignment.rebalance(slog.live_degree_histogram()))
    eager = ReshardPolicy(spread_threshold=0.0, min_slides=0)
    assert plan_reshard(slog, eager, slides_since=99) is None


def test_occupancy_spread_recovery_on_hub_drift():
    """The workload argument: on a hub-drift stream a fixed layout degrades
    to the skew ceiling while periodic policy resharding holds the live
    spread near even — and recovery is a single rebalance away."""
    base, deltas = hub_drift_stream()
    fixed = ShardedSnapshotLog(V, 4, capacity=64, assignment="balanced",
                               degree_hist=degree_histogram(base, [], V))
    online = ShardedSnapshotLog(V, 4, capacity=64, assignment="balanced",
                                degree_hist=degree_histogram(base, [], V))
    fixed.append_snapshot(*base)
    online.append_snapshot(*base)
    pol = ReshardPolicy(spread_threshold=1.5, min_slides=4,
                        on_capacity_growth=False)
    slides = 0
    online_spreads = []
    for d in deltas:
        fixed.append_snapshot(*d)
        online.append_snapshot(*d)
        slides += 1
        got = plan_reshard(online, pol, slides_since=slides)
        if got is not None:
            online.reshard(got)
            slides = 0
        online_spreads.append(online.occupancy_spread())
    assert fixed.occupancy_spread() > 2.0, fixed.occupancy_spread()
    assert max(online_spreads[-8:]) <= 2.0, online_spreads
    assert online.occupancy_spread() < fixed.occupancy_spread()
    assert online.assignment.epoch >= 1
    # a single recovery rebalance fixes even the degraded fixed log
    fixed.reshard(fixed.assignment.rebalance(fixed.live_degree_histogram()))
    assert fixed.occupancy_spread() <= 2.0


def test_query_batcher_policy_migration_bit_for_bit():
    """``QueryBatcher(reshard_policy=...)`` migrates a served view when the
    policy fires and keeps serving bit-for-bit; the derived-layout dedup
    stops repeat migrations once the layout is balanced."""
    from repro.obs.metrics import MetricsRegistry, use_registry

    base, deltas = make_stream(seed=9)
    rlog, pending = hash_slog(base, deltas)
    rview = ShardedWindowView(rlog, size=WINDOW)
    ref_qb = QueryBatcher()
    ref_qb.watch(rview, "sssp", 0)
    ref_qb.watch(rview, "bfs", 7)

    slog, _ = hash_slog(base, deltas)
    sview = ShardedWindowView(slog, size=WINDOW)
    with use_registry(MetricsRegistry()) as reg:
        qb = QueryBatcher(reshard_policy=ReshardPolicy(
            spread_threshold=0.5, min_slides=2, on_capacity_growth=False,
        ))
        qb.watch(sview, "sssp", 0)
        qb.watch(sview, "bfs", 7)
        for k, d in enumerate(pending):
            want = ref_qb.advance_window(rview, d)
            got = qb.advance_window(sview, d)
            for key in want:
                np.testing.assert_array_equal(
                    got[key], want[key], err_msg=f"slide {k} {key}"
                )
        assert slog.assignment.epoch == 1  # fired once, then deduped
        assert reg.counter("serving_reshards_total").value() == 1


def test_query_batcher_pipelined_path_reshards():
    """The async serving path runs the same policy check inside the worker
    job — migration is pipelined, not a stop-the-world stall."""
    base, deltas = make_stream(seed=10)
    rlog, pending = hash_slog(base, deltas)
    rview = ShardedWindowView(rlog, size=WINDOW)
    ref_qb = QueryBatcher()
    ref_qb.watch(rview, "sssp", 0)

    slog, _ = hash_slog(base, deltas)
    sview = ShardedWindowView(slog, size=WINDOW)
    qb = QueryBatcher(reshard_policy=ReshardPolicy(
        spread_threshold=0.5, min_slides=2, on_capacity_growth=False,
    ))
    qb.watch(sview, "sssp", 0)
    try:
        handles = [qb.advance_window_async(sview, d) for d in pending]
        for k, (h, d) in enumerate(zip(handles, pending)):
            want = ref_qb.advance_window(rview, d)
            got = h.result()
            np.testing.assert_array_equal(
                got[("sssp", 0)], want[("sssp", 0)], err_msg=f"slide {k}"
            )
        assert slog.assignment.epoch == 1
    finally:
        qb.close()


def test_serve_supervisor_policy_migration():
    """``ServeSupervisor(reshard_policy=...)`` live-migrates its replica
    mid-run, serves identically to an unsupervised stream, and emits a
    structured ``reshard`` event."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.ft.recovery import ServeSupervisor
    from repro.obs.export import EventLog

    base, deltas = make_stream(seed=11)
    rlog, pending = hash_slog(base, deltas)
    ref_sq = StreamingQuery(ShardedWindowView(rlog, size=WINDOW), "sswp", 5)
    ref = []
    for d in pending:
        ref_sq.advance(d)
        ref.append(np.asarray(ref_sq.results).copy())

    slog, _ = hash_slog(base, deltas)
    sq = StreamingQuery(ShardedWindowView(slog, size=WINDOW), "sswp", 5)
    import tempfile

    events = EventLog()
    with tempfile.TemporaryDirectory() as d:
        sup = ServeSupervisor(
            CheckpointManager(d), ckpt_every=100, events=events,
            reshard_policy=ReshardPolicy(spread_threshold=0.5, min_slides=2,
                                         on_capacity_growth=False),
        )
        replica, served, stats = sup.run(sq, pending)
    assert stats["restarts"] == 0
    for k, (got, want) in enumerate(zip(served, ref)):
        np.testing.assert_array_equal(got, want, err_msg=f"slide {k}")
    assert slog.assignment.epoch == 1
    kinds = [e["event"] for e in events.events]
    assert "reshard" in kinds
    ev = next(e for e in events.events if e["event"] == "reshard")
    assert ev["n_shards"] == 1 and ev["epoch"] == 1
    assert ev["bytes_moved"] > 0


# ===================================== checkpoints: reshard/delta/background
def test_reshard_then_checkpoint_then_restore_roundtrip():
    """A migrated replica checkpoints and restores like any other: the saved
    global-space values re-enter the post-migration layout and every later
    slide stays bit-for-bit."""
    from repro.checkpoint import resume_streaming, streaming_state

    base, deltas = make_stream(seed=12)
    rlog, pending = hash_slog(base, deltas)
    ref_sq = StreamingQuery(
        ShardedWindowView(rlog, size=WINDOW), "sssp", 0, method="cqrs_ell"
    )
    ref = [np.asarray(ref_sq.results).copy()]
    for d in pending:
        ref_sq.advance(d)
        ref.append(np.asarray(ref_sq.results).copy())

    slog, _ = hash_slog(base, deltas)
    sq = StreamingQuery(
        ShardedWindowView(slog, size=WINDOW), "sssp", 0, method="cqrs_ell"
    )
    sq.results
    sq.advance(pending[0])
    sq.reshard()
    sq.advance(pending[1])
    tree, extra = streaming_state(sq)
    restored = resume_streaming(tree, extra)
    np.testing.assert_array_equal(np.asarray(restored.results), ref[2])
    for j, d in enumerate(pending[2:], start=2):
        restored.advance(d)
        sq.advance(d)
        np.testing.assert_array_equal(np.asarray(sq.results), ref[j + 1])
        np.testing.assert_array_equal(
            np.asarray(restored.results), ref[j + 1],
            err_msg=f"restored replica diverged at slide {j}",
        )


def test_delta_encoded_window_payload():
    """``encoding="delta"`` stores O(window·batch) instead of O(window·E),
    rebuilds the identical window (membership, weights, extrema), and the
    legacy ``"full"`` layout keeps restoring."""
    from repro.checkpoint.streamstate import rebuild_view, window_payload

    base, deltas = make_stream(seed=13, num_snapshots=8, batch_size=12)
    log = SnapshotLog.from_stream(base, deltas, V)
    view = WindowView(log, size=5)
    view.slide_to_tip()

    with pytest.raises(ValueError, match="encoding"):
        window_payload(view, encoding="zstd")

    outs = {}
    for enc in ("delta", "full"):
        tree, meta = window_payload(view, encoding=enc)
        assert meta["encoding"] == enc
        rv = rebuild_view(tree, meta)
        outs[enc] = sum(a.nbytes for a in tree.values())
        # the rebuilt log reproduces window weight extrema exactly
        ref = EvolvingQuery(view.materialize(), "sswp", 5).evaluate("cqrs")
        got = EvolvingQuery(rv.materialize(), "sswp", 5).evaluate("cqrs")
        np.testing.assert_array_equal(got, ref)
    assert outs["delta"] < outs["full"], outs

    # sharded views delta-encode too (global ids concatenated across shards)
    slog = ShardedSnapshotLog.from_stream(base, deltas, V, n_shards=4,
                                          capacity=64)
    sview = ShardedWindowView(slog, size=5)
    sview.slide_to_tip()
    tree, meta = window_payload(sview)
    assert meta["encoding"] == "delta" and meta["sharded"]
    rv = rebuild_view(tree, meta)
    ref = EvolvingQuery(sview.materialize(), "sssp", 0).evaluate("cqrs")
    got = EvolvingQuery(rv.materialize(), "sssp", 0).evaluate("cqrs")
    np.testing.assert_array_equal(got, ref)


def test_background_checkpoint_never_blocks_serving():
    """``checkpoint_state_async`` returns immediately even while the worker
    is busy — serialization rides the FIFO pipeline; the serve thread never
    waits on it — and yields the same payload as the synchronous path."""
    base, deltas = make_stream(seed=14)
    slog, pending = hash_slog(base, deltas)
    sview = ShardedWindowView(slog, size=WINDOW)
    qb = QueryBatcher()
    qb.watch(sview, "sssp", 0)
    try:
        qb.advance_window(sview, pending[0])
        gate = threading.Event()
        qb._ensure_executor().submit(gate.wait)  # occupy the worker
        fut = qb.checkpoint_state_async(sview)   # must NOT block here
        assert not fut.done()  # queued behind the gate, not run inline
        gate.set()
        tree, extra = fut.result(timeout=60)
        ref_tree, ref_extra = qb.checkpoint_state(sview)
        assert extra == ref_extra
        assert set(tree) == set(ref_tree)
        for k in tree:
            np.testing.assert_array_equal(tree[k], ref_tree[k], err_msg=k)
        # the captured state restores into a serving batcher that picks the
        # stream back up bit-for-bit
        qb2, view2 = QueryBatcher.resume(tree, extra)
        want = qb.advance_window(sview, pending[1])
        got = qb2.advance_window(view2, pending[1])
        np.testing.assert_array_equal(got[("sssp", 0)], want[("sssp", 0)])
        qb2.close()
    finally:
        qb.close()


# ========================================================= first-boot ladder
def test_observed_ell_ladder_checkpointed():
    """The packer records every sticky row class it enters; ``ladder_specs``
    turns that into grid points and ``grid.json`` round-trips them — a
    first boot pre-traces the data-dependent ladder a prior run walked."""
    from repro.graph.ell import StableEllPacker
    from repro.serving.warmstart import (
        grid_for,
        ladder_specs,
        load_grid,
        observed_ell_ladder,
        save_grid,
    )

    p = StableEllPacker(16, slot_width=4, row_align=2)
    p.pack([0, 1], [2, 3], [1.0, 1.0])
    first = p.num_rows
    p.pack(list(range(12)), [i % 16 for i in range(12)],
           [1.0] * 12)  # forces a class transition
    assert p.class_history[0] == first
    assert p.class_history == sorted(set(p.class_history))
    assert len(p.class_history) >= 2

    base, deltas = make_stream(seed=15)
    slog, pending = hash_slog(base, deltas)
    sq = StreamingQuery(
        ShardedWindowView(slog, size=WINDOW), "sssp", 0, method="cqrs_ell"
    )
    sq.results
    for d in pending:
        sq.advance(d)
    ladder = observed_ell_ladder(sq)
    assert ladder, "live cqrs_ell query recorded no ELL classes"
    specs = ladder_specs(sq)
    assert specs[0] == grid_for(sq)
    spec_rows = {s.ell_rows for s in specs}
    assert set(ladder) <= spec_rows

    import tempfile

    with tempfile.TemporaryDirectory() as d:
        save_grid(specs, d)
        loaded = load_grid(d)
        assert [s.key() for s in loaded] == [s.key() for s in specs]

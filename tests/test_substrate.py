"""Optimizer, checkpoint/restart, straggler, heartbeat, compression, scheduler."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.compression import (
    dequantize_int8,
    ef_compress_tree,
    init_residuals,
    quantize_int8,
)
from repro.ft.heartbeat import HeartbeatMonitor
from repro.ft.recovery import TrainSupervisor
from repro.ft.straggler import StragglerDetector
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import warmup_cosine


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state, m = adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-3 * l0
    assert float(m["grad_norm"]) >= 0.0


def test_grad_clip_bounds_update():
    params = {"w": jnp.array([0.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10, grad_clip=1.0,
                      weight_decay=0.0)
    huge = {"w": jnp.array([1e9])}
    new, state, m = adamw_update(huge, state, params, cfg)
    assert abs(float(new["w"][0])) <= 1.1e-2  # clipped to ~lr


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10, total_steps=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[99] < lrs[50] < lrs[12]


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.int32(7)}}
    mgr.save(10, tree, extra={"note": "x"})
    mgr.save(20, tree)
    mgr.save(30, tree)
    assert mgr.steps() == [20, 30]  # keep=2 collected step 10
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert int(restored["b"]["c"]) == 7


def test_checkpoint_detects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.zeros((3, 3))})


def test_supervisor_recovers_from_injected_failures(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    failures = {7, 13}  # steps that die once each

    def step_fn(state, step):
        if step in failures:
            failures.discard(step)
            raise RuntimeError("injected preemption")
        return {"x": state["x"] + 1}

    sup = TrainSupervisor(mgr, ckpt_every=5)
    state, stats = sup.run({"x": jnp.int32(0)}, step_fn, 20)
    assert int(state["x"]) == 20  # exactly-once net effect per surviving step
    assert stats["restarts"] == 2


# ---------------------------------------------------------------- ft
def test_straggler_detection_and_plans():
    det = StragglerDetector(num_workers=4, deadline_factor=2.0)
    for _ in range(8):
        det.record_step([1.0, 1.1, 0.9, 1.0])
    slow = [1.0, 1.0, 5.0, 1.0]
    assert det.stragglers(slow) == [2]
    plan = det.plan(slow, policy="redistribute")
    assert plan[2]["action"] == "redistribute" and plan[2]["to"] != 2
    assert det.plan(slow, policy="skip")[2]["action"] == "skip"
    assert det.plan([1.0] * 4) == {}


def test_heartbeat_death_and_readmit():
    t = [0.0]
    mon = HeartbeatMonitor(num_workers=3, timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat(0); mon.beat(1)
    t[0] = 12.0
    assert mon.dead_workers() == {2}
    assert mon.alive_count() == 2
    mon.readmit(2)
    assert mon.dead_workers() == set()


# ---------------------------------------------------------------- compression
def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x).max()
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_telescopes():
    """Sum of EF-compressed grads ≈ sum of true grads (bias telescopes)."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) for _ in range(50)]
    params = {"w": jnp.zeros(64)}
    res = init_residuals(params)
    total_true = jnp.zeros(64)
    total_comp = jnp.zeros(64)
    for g in grads:
        cg, res = ef_compress_tree({"w": g}, res)
        total_true += g
        total_comp += cg["w"]
    # residual bound: remaining error is the last residual only
    np.testing.assert_allclose(
        np.asarray(total_comp + res["w"]), np.asarray(total_true), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------- scheduler
def test_request_scheduler_drains_queue():
    from repro.serving.scheduler import Request, RequestScheduler

    sched = RequestScheduler(batch_size=2, eos_id=99)
    for uid in range(5):
        sched.submit(Request(uid=uid, prompt=[1, 2, 3], max_new_tokens=4))

    def fake_decode(tokens, positions, mask):
        return jnp.where(positions >= 5, 99, tokens + 1)  # EOS after a few tokens

    done = sched.run(fake_decode, max_steps=200)
    assert len(done) == 5
    assert all(r.done for r in done)
    assert all(len(r.generated) <= 4 for r in done)

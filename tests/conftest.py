"""Shared test fixtures/utilities.

NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the single real CPU device.  Multi-device tests spawn
subprocesses (see tests/test_dryrun_small.py).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.structures import build_evolving_graph


def make_evolving(
    num_vertices=64,
    num_edges=256,
    num_snapshots=6,
    batch_size=24,
    seed=0,
    readd_prob=0.3,
):
    """Small evolving RMAT graph for correctness tests."""
    src, dst = generate_rmat(num_vertices, num_edges, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    (bs, bd, bw), deltas = generate_evolving_stream(
        src, dst, w, num_vertices,
        num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=readd_prob, seed=seed + 2,
    )
    return build_evolving_graph(bs, bd, bw, deltas, num_vertices)


@pytest.fixture(scope="session")
def small_evolving():
    return make_evolving()


def reference_fixpoint(src, dst, w, valid, sr, source, num_vertices):
    """Pure-numpy Bellman-Ford oracle for a path semiring."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    w = np.asarray(w)
    valid = np.asarray(valid)
    vals = np.full(num_vertices, sr.identity, np.float32)
    vals[source] = np.float32(sr.source)
    for _ in range(num_vertices + 1):
        prev = vals.copy()
        for e in np.flatnonzero(valid):
            cand = np.float32(sr.extend(np.float32(vals[src[e]]), np.float32(w[e])))
            if sr.minimize:
                vals[dst[e]] = min(vals[dst[e]], cand)
            else:
                vals[dst[e]] = max(vals[dst[e]], cand)
        if np.array_equal(prev, vals):
            break
    return vals

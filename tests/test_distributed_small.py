"""Multi-device (8 host devices) shard_map/pjit tests via subprocess.

Subprocesses are required because xla_force_host_platform_device_count must
be set before jax initializes — the main pytest process keeps 1 device.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "_distributed_checks.py")


def _run(check: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + os.path.dirname(__file__)
    )
    out = subprocess.run(
        [sys.executable, SCRIPT, check],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert out.returncode == 0, f"{check} failed:\n{out.stdout}\n{out.stderr}"
    assert "CHECK_OK" in out.stdout


@pytest.mark.parametrize(
    "check",
    ["evolve", "compressed_psum", "pipeline", "dlrm_sharded_lookup",
     "lm_spmd_step", "elastic_checkpoint", "folded_evolve"],
)
def test_distributed(check):
    _run(check)

"""Streaming window subsystem: slide-equivalence, witness trims, QRS patching.

The core contract: ``StreamingQuery.advance()`` over K successive slides is
**bit-for-bit** equal to a fresh ``EvolvingQuery`` on each slid window's
materialized graph, for both the flat-XLA (``cqrs``) and Pallas/ELL
(``cqrs_ell``) engines — monotone fixpoints are unique, so warm incremental
state must land on exactly the same floats.

Also covered: the retire path where the retired snapshot was the *sole
witness* of a bound (the witness-count trim must fire), safe-weight widening
on an appended snapshot (the G∩-weight-worsens-as-deletion path), patched-QRS
equivalence to a fresh ``build_qrs``, universe capacity growth under a live
query, and the ``QueryBatcher.advance_window`` warm-state serving hook.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.api import EvolvingQuery, StreamingQuery
from repro.core.bounds import compute_bounds
from repro.core.qrs import build_qrs
from repro.core.semiring import SEMIRINGS
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.stream import SnapshotLog, WindowView
from repro.serving.scheduler import QueryBatcher
from _prop import given, settings, st

V = 48
WINDOW = 3
NO_DELTA = ((), (), (), (), ())


def make_stream(seed: int, *, num_snapshots: int = WINDOW + 3, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def make_log(seed: int, *, capacity: int = 512):
    """Log primed with WINDOW snapshots; returns (log, remaining deltas)."""
    base, deltas = make_stream(seed)
    log = SnapshotLog(V, capacity=capacity)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    return log, deltas[WINDOW - 1:]


def fresh_eval(view, query: str, source: int) -> np.ndarray:
    return EvolvingQuery(view.materialize(), query, source).evaluate("cqrs")


# -------------------------------------------------------------------- slides
@pytest.mark.parametrize("query", ["sssp", "sswp", "ssnp"])
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_k_slides_match_fresh(query, method):
    log, pending = make_log(seed=0)
    view = WindowView(log, size=WINDOW)
    sq = StreamingQuery(view, query, 0, method=method)
    np.testing.assert_array_equal(sq.results, fresh_eval(view, query, 0))
    for k, delta in enumerate(pending):
        got = sq.advance(delta)
        np.testing.assert_array_equal(
            got, fresh_eval(view, query, 0),
            err_msg=f"{query}/{method} diverged at slide {k}",
        )
    assert sq.stats["slides"] == len(pending)
    assert sq.stats["method"] == f"stream[{method}]"


@settings(max_examples=6)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(["bfs", "sssp", "viterbi"]),
    source=st.integers(0, V - 1),
)
def test_stream_advance_property(seed, query, source):
    """Seed-swept: K successive advances ≡ fresh evaluation on each window."""
    log, pending = make_log(seed=seed)
    view = WindowView(log, size=WINDOW)
    sq = StreamingQuery(view, query, source)
    np.testing.assert_array_equal(sq.results, fresh_eval(view, query, source))
    for delta in pending[:2]:
        np.testing.assert_array_equal(
            sq.advance(delta), fresh_eval(view, query, source)
        )


def test_multi_slide_catch_up_in_one_advance():
    """Appending several snapshots then advancing once must equal stepwise."""
    log, pending = make_log(seed=7)
    view = WindowView(log, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0)
    sq.results
    for delta in pending:  # queue everything, no advance in between
        log.append_snapshot(*delta)
    got = sq.advance()
    np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))
    assert sq.stats["advanced"] == len(pending)
    # warm state stays coherent for further single slides
    got = sq.advance(([1, 2], [0, 3], [2.5, 1.25], [], []))  # add-only delta
    np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))


def test_advance_is_idempotent_without_new_snapshots():
    log, pending = make_log(seed=1)
    sq = StreamingQuery(WindowView(log, size=WINDOW), "sssp", 0)
    first = sq.advance(pending[0])
    again = sq.advance()  # nothing new appended
    np.testing.assert_array_equal(first, again)
    assert sq.stats["advanced"] == 0


# ------------------------------------------------------- witness-trim paths
def test_retire_path_sole_witness():
    """Retiring the only snapshot witnessing a bound must trigger the trim.

    Snapshot 0 alone contains 0→1 (w=1); its retirement drops the edge from
    G∪, so val_cup[1] (and, transitively through 1→3, val_cup[3]) must worsen
    to the 0→2→1 detour — caught only if the witness-count trim invalidates
    the parent chains rooted at the dropped edge.
    """
    log = SnapshotLog(5, capacity=64)
    log.append_snapshot([0, 0, 2, 1], [1, 2, 1, 3], [1.0, 4.0, 4.0, 1.0])
    log.append_snapshot([], [], [], [0], [1])  # snapshot 1: delete 0→1
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, "sssp", 0)
    before = np.asarray(sq.bounds.val_cup).copy()
    assert before[1] == 1.0 and before[3] == 2.0

    got = sq.advance(NO_DELTA)  # window [0,2) → [1,3): snapshot 0 retires
    np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))
    after = np.asarray(sq.bounds.val_cup)
    assert after[1] == 8.0 and after[3] == 9.0  # both bounds actually worsened
    ref = compute_bounds(view.materialize(), SEMIRINGS["sssp"], 0)
    np.testing.assert_array_equal(after, np.asarray(ref.val_cup))
    np.testing.assert_array_equal(
        np.asarray(sq.bounds.val_cap), np.asarray(ref.val_cap)
    )


@pytest.mark.parametrize("query,cycle_w", [("sswp", 9.0), ("ssnp", 1.0)])
def test_equal_value_cycle_does_not_survive_support_deletion(query, cycle_w):
    """Regression: an equal-value cycle must not self-justify through the trim.

    With a non-strict ``extend`` (sswp's min / ssnp's max) both cycle
    vertices hold the same value and every cycle edge is achieving, so an
    arbitrary achieving-edge parent choice records them as each other's
    parents; deleting their sole support edge then invalidates nothing and
    the stale too-good value outlives monotone re-relaxation — silently
    breaking the bit-for-bit contract.
    """
    log = SnapshotLog(5, capacity=64)
    # append order fixes universe ids: cycle edges 1↔2 get ids 0/1, the
    # support edge 0→1 (the cycle's only connection to the source) id 2
    log.append_snapshot([1, 2, 0], [2, 1, 1], [cycle_w, cycle_w, 5.0])
    log.append_snapshot([], [], [])
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, query, 0)
    assert float(np.asarray(sq.results)[-1, 1]) == 5.0

    got = sq.advance(([], [], [], [0], [1]))  # delete the support edge
    np.testing.assert_array_equal(got, fresh_eval(view, query, 0))
    ident = SEMIRINGS[query].identity
    assert float(got[-1, 1]) == ident and float(got[-1, 2]) == ident


@pytest.mark.parametrize("query", ["sssp", "sswp"])
def test_weight_widening_on_appended_snapshot(query):
    """Re-adding a present edge with a worse weight widens the G∩ safe weight;
    the streaming bounds must treat the old-weight edge as deleted."""
    log = SnapshotLog(3, capacity=64)
    worse = 9.0 if query == "sssp" else 0.5  # sssp: wmax grows; sswp: wmin shrinks
    log.append_snapshot([0, 0, 2], [1, 2, 1], [2.0, 5.0, 3.0])
    log.append_snapshot(NO_DELTA[0], NO_DELTA[1], NO_DELTA[2])  # snapshot 1
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, query, 0)
    sq.results
    # snapshot 2 re-adds 0→1 with the worse weight while it is still present
    got = sq.advance(([0], [1], [worse], [], []))
    np.testing.assert_array_equal(got, fresh_eval(view, query, 0))
    ref = compute_bounds(view.materialize(), SEMIRINGS[query], 0)
    np.testing.assert_array_equal(
        np.asarray(sq.bounds.val_cap), np.asarray(ref.val_cap)
    )


def test_weight_widening_mid_catch_up():
    """Queued slides where a later one widens extrema must not fold stale.

    Regression: intermediate catch-up slides see the log's *final* lifetime
    weights, so parents recomputed there are inconsistent with pre-widening
    values and the widening slide's trim finds no parent to invalidate —
    StreamingQuery must detect this and rebuild instead.
    """
    log = SnapshotLog(4, capacity=64)
    log.append_snapshot([0, 0, 2, 1], [1, 2, 1, 3], [2.0, 5.0, 3.0, 1.0])
    log.append_snapshot([], [], [])  # identical snapshot 1
    view = WindowView(log, size=2)
    sq = StreamingQuery(view, "sssp", 0)
    sq.results
    log.append_snapshot([], [], [], [1], [3])       # snapshot 2: delete 1→3
    log.append_snapshot([0], [1], [9.0], [], [])    # snapshot 3: widen 0→1
    got = sq.advance()  # one catch-up over both queued slides
    np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))
    ref = compute_bounds(view.materialize(), SEMIRINGS["sssp"], 0)
    np.testing.assert_array_equal(
        np.asarray(sq.bounds.val_cap), np.asarray(ref.val_cap)
    )
    assert float(np.asarray(sq.bounds.val_cap)[1]) == 8.0  # 0→2→1, not stale 2.0


# ------------------------------------------------------------- QRS patching
def test_patched_qrs_matches_fresh_build():
    log, pending = make_log(seed=2)
    view = WindowView(log, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0)
    sq.results
    sr = SEMIRINGS["sssp"]
    for delta in pending:
        sq.advance(delta)
        mat = view.materialize()
        b = compute_bounds(mat, sr, 0)
        q = build_qrs(mat, b.uvv, b.val_cap, sr)
        valid = np.asarray(q.valid)
        fresh = set(zip(np.asarray(q.src)[valid].tolist(),
                        np.asarray(q.dst)[valid].tolist()))
        ids = sq.qrs.edge_ids()
        patched = set(zip(log.src[ids].tolist(), log.dst[ids].tolist()))
        assert patched == fresh
        assert sq.qrs.num_edges == len(ids)


def test_capacity_growth_under_live_query(monkeypatch):
    """Universe growth (array-shape change) mid-stream must stay transparent."""
    import repro.graph.stream as stream_mod

    monkeypatch.setattr(stream_mod, "STREAM_ALIGN", 8)
    base, deltas = make_stream(seed=3)
    probe = SnapshotLog(V, capacity=8)
    probe.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        probe.append_snapshot(*d)
    # rebuild with capacity exactly full at prime: the first post-prime delta
    # that registers a fresh edge forces a growth under the live query
    log = SnapshotLog(V, capacity=probe.num_edges)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    cap_at_prime = log.capacity
    view = WindowView(log, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0)
    sq.results
    grew = False
    for delta in deltas[WINDOW - 1:]:
        got = sq.advance(delta)
        grew |= log.capacity > cap_at_prime
        np.testing.assert_array_equal(got, fresh_eval(view, "sssp", 0))
    assert grew, "test graph never grew the universe; weaken STREAM_ALIGN"


# ------------------------------------------------------------------ serving
def test_query_batcher_advance_window_warm_state():
    log, pending = make_log(seed=4)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher()
    sq1 = qb.watch(view, "sssp", 0)
    sq2 = qb.watch(view, "bfs", 7)
    assert qb.watch(view, "sssp", 0) is sq1  # idempotent registration
    assert len(qb.watching(view)) == 2
    for delta in pending:
        out = qb.advance_window(view, delta)
        assert set(out) == {("sssp", 0), ("bfs", 7)}
        for (qname, s), res in out.items():
            np.testing.assert_array_equal(res, fresh_eval(view, qname, s))
    assert sq1.stats["slides"] == len(pending)
    assert sq2.stats["slides"] == len(pending)


def test_history_pruning_and_slow_consumer_rebuild():
    """advance_window prunes consumed history; a pruned-past consumer re-primes."""
    log, pending = make_log(seed=8)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher()
    qb.watch(view, "sssp", 0)
    for delta in pending[:2]:
        qb.advance_window(view, delta)
    assert len(view.history) == 0  # fully consumed history was pruned
    assert view.history_end == 2

    # a straggler that registered before the pruned slides must rebuild
    straggler = StreamingQuery(view, "bfs", 3)
    straggler._diff_pos = 0  # simulate state from before the pruning
    straggler._bounds = object()  # non-None: forces the catch-up path
    got = straggler.advance(pending[2])
    np.testing.assert_array_equal(got, fresh_eval(view, "bfs", 3))


def test_streaming_query_validation():
    log, _ = make_log(seed=5)
    view = WindowView(log, size=WINDOW)
    with pytest.raises(ValueError):
        StreamingQuery(view, "sssp", 0, method="kickstarter")
    with pytest.raises(ValueError):
        StreamingQuery(view, "sssp", 0, window=WINDOW + 1)
    with pytest.raises(KeyError):
        log.append_snapshot([], [], [], [0], [0])  # delete an absent edge
    with pytest.raises(IndexError):
        view.snapshot_mask(log.num_snapshots + 5)


def test_append_snapshot_is_atomic_on_bad_deletion():
    """A delta with one bad deletion must not half-mutate the log tip."""
    log = SnapshotLog(4, capacity=64)
    log.append_snapshot([0, 1], [1, 2], [1.0, 2.0])
    before = log.snapshot_edges(0).copy()
    with pytest.raises(KeyError):
        log.append_snapshot([], [], [], [0, 3], [1, 2])  # 0→1 ok, 3→2 absent
    assert log.num_snapshots == 1
    ok = log.append_snapshot([], [], [])  # tip unchanged: 0→1 still present
    np.testing.assert_array_equal(log.snapshot_edges(ok), before)


def test_append_snapshot_rejects_out_of_range_ids():
    """Out-of-range ids would alias distinct edges in the src*V+dst keying;
    the whole delta must be rejected before any tip mutation."""
    log = SnapshotLog(4, capacity=64)
    log.append_snapshot([0], [1], [1.0])
    for bad in (4, -1):
        with pytest.raises(ValueError):
            log.append_snapshot([bad], [0], [1.0])
        with pytest.raises(ValueError):
            log.append_snapshot([0], [bad], [1.0])
        with pytest.raises(ValueError):
            log.append_snapshot([], [], [], [bad], [1])
    with pytest.raises(ValueError):
        log.append_snapshot([0, 1], [2], [1.0, 1.0])  # length mismatch
    assert log.num_snapshots == 1
    ok = log.append_snapshot([], [], [])  # tip unchanged by the rejections
    np.testing.assert_array_equal(log.snapshot_edges(ok), log.snapshot_edges(0))


def test_in_edges_matches_per_vertex_slices():
    log, _ = make_log(seed=11)
    indptr, ids = log.in_edge_csr()
    verts = np.asarray([0, 5, 3, V - 1, 5, 2], np.int32)
    naive = np.concatenate(
        [ids[indptr[int(v)]:indptr[int(v) + 1]] for v in verts]
    ).astype(np.int32)
    np.testing.assert_array_equal(log.in_edges(verts), naive)
    assert log.in_edges(np.asarray([], np.int32)).size == 0


def test_private_view_history_is_pruned():
    """A StreamingQuery built from a log owns its view and prunes history."""
    log, pending = make_log(seed=9)
    sq = StreamingQuery(log, "sssp", 0, window=WINDOW)
    sq.results
    for delta in pending:
        sq.advance(delta)
    assert len(sq.view.history) == 0  # consumed-and-owned → pruned
    assert sq.view.history_end == len(pending)


# ------------------------------------------------------- history compaction
def test_prune_history_retires_log_prefix():
    """A pruning consumer retires pre-window id arrays to delta storage."""
    log, pending = make_log(seed=12)
    sq = StreamingQuery(log, "sssp", 0, window=WINDOW)  # private view: prunes
    sq.results
    for delta in pending:
        sq.advance(delta)
    view = sq.view
    assert view.start == len(pending)
    assert log.retired_upto == view.start  # everything pre-window retired
    for t in range(log.retired_upto):
        with pytest.raises(LookupError):
            log.snapshot_edges(t)
        log.snapshot_delta(t)  # the bounded per-snapshot record survives
    # live window still fully materializable and correct
    np.testing.assert_array_equal(sq.advance(), fresh_eval(view, "sssp", 0))


def test_new_consumer_on_compacted_log():
    """A new StreamingQuery/WindowView on a shared log must stay
    constructible after history compaction retired the log's prefix —
    the default window starts at the earliest materializable snapshot."""
    log, pending = make_log(seed=21)
    sq1 = StreamingQuery(log, "sssp", 0, window=WINDOW)
    sq1.results
    for delta in pending:
        sq1.advance(delta)
    assert log.retired_upto > 0
    sq2 = StreamingQuery(log, "bfs", 1, window=WINDOW)  # post-compaction
    np.testing.assert_array_equal(sq2.results, fresh_eval(sq2.view, "bfs", 1))
    with pytest.raises(LookupError):
        WindowView(log, size=WINDOW, start=0)  # explicit retired start: loud


def test_snapshot_delta_matches_membership_transitions():
    log, _ = make_log(seed=13)
    for t in range(1, log.num_snapshots):
        prev = log.snapshot_edges(t - 1)
        cur = log.snapshot_edges(t)
        added, removed = log.snapshot_delta(t)
        np.testing.assert_array_equal(np.sort(added), np.setdiff1d(cur, prev))
        np.testing.assert_array_equal(np.sort(removed), np.setdiff1d(prev, cur))
    added0, removed0 = log.snapshot_delta(0)
    np.testing.assert_array_equal(np.sort(added0), log.snapshot_edges(0))
    assert len(removed0) == 0


def test_retirement_respects_every_registered_view():
    """The watermark is the min over live views; a straggler view pins it."""
    log, pending = make_log(seed=14)
    for d in pending:
        log.append_snapshot(*d)
    lagging = WindowView(log, size=WINDOW, start=0)  # never slides
    leading = WindowView(log, size=WINDOW, start=0)
    leading.slide_to_tip()
    leading.prune_history(leading.history_end)
    assert log.retired_upto == 0  # pinned by the lagging view
    assert lagging.union_mask() is not None  # still usable
    del lagging  # weakly registered: dropping the view unpins it
    leading.prune_history(leading.history_end)
    assert log.retired_upto == leading.start
    # history replay still possible from the leading view's retained state
    with pytest.raises(LookupError):
        log.snapshot_mask(0)


def test_no_retirement_without_views():
    log, pending = make_log(seed=15)
    assert log.retire_history() == 0  # make_log's views died; none registered
    for t in range(log.num_snapshots):
        log.snapshot_edges(t)  # everything still materializable


# --------------------------------------------------- warm-state cache bounds
def test_stream_cache_lru_eviction_and_info():
    log, pending = make_log(seed=16)
    view = WindowView(log, size=WINDOW)
    qb = QueryBatcher(stream_capacity=2)
    sq1 = qb.watch(view, "sssp", 0)
    qb.watch(view, "bfs", 1)
    # hits, misses, evictions, size, max (lane_supersteps rides at the end)
    assert qb.cache_info()[:5] == (0, 2, 0, 2, 2)
    assert qb.watch(view, "sssp", 0) is sq1  # hit refreshes recency
    qb.watch(view, "sswp", 2)  # evicts LRU = ("bfs", 1)
    info = qb.cache_info()
    assert (info.hits, info.misses, info.evictions) == (1, 3, 1)
    assert info.currsize == 2 and info.maxsize == 2
    names = {(sq.semiring.name, sq.source) for sq in qb.watching(view)}
    assert names == {("sssp", 0), ("sswp", 2)}
    # the evicted entry re-primes on the next watch (a miss, not an error)
    qb.watch(view, "bfs", 1)  # evicts the now-LRU sssp entry
    assert qb.cache_info().misses == 4
    out = qb.advance_window(view, pending[0])
    assert len(out) == 2  # the two resident watchers (sswp, bfs) are served
    for (qname, s), res in out.items():
        np.testing.assert_array_equal(res, fresh_eval(view, qname, s))


def test_stream_cache_ttl_eviction():
    log_a, _ = make_log(seed=17)
    log_b, _ = make_log(seed=18)
    view_a = WindowView(log_a, size=WINDOW)
    view_b = WindowView(log_b, size=WINDOW)
    now = [0.0]
    qb = QueryBatcher(stream_ttl=10.0, clock=lambda: now[0])
    qb.watch(view_a, "sssp", 0)
    now[0] = 5.0
    qb.watch(view_b, "bfs", 1)  # within TTL: A survives (and is not exempt)
    assert qb.cache_info().currsize == 2
    now[0] = 16.0  # A idle for 16s > ttl; B idle 11s > ttl
    qb.watch(view_b, "bfs", 1)  # housekeeping: A evicted; B exempt (its view)
    info = qb.cache_info()
    assert info.evictions == 1 and info.currsize == 1
    assert {sq.view for sq in qb.watching()} == {view_b}


def test_abandoned_watcher_expires_on_served_view():
    """Serving must not refresh TTL idleness: a watcher nobody re-watches
    expires even though advance_window serves its view every slide."""
    log, pending = make_log(seed=22)
    view = WindowView(log, size=WINDOW)
    now = [0.0]
    qb = QueryBatcher(stream_ttl=10.0, clock=lambda: now[0])
    qb.watch(view, "sssp", 0)     # kept alive by re-watching below
    qb.watch(view, "bfs", 1)      # abandoned after registration
    for k, delta in enumerate(pending):
        now[0] += 6.0
        qb.watch(view, "sssp", 0)  # the live client touches its entry
        out = qb.advance_window(view, delta)
        if k == 0:
            assert set(out) == {("sssp", 0), ("bfs", 1)}
    # bfs idled past the TTL despite being served every slide
    assert set(out) == {("sssp", 0)}
    assert qb.cache_info().evictions == 1
    np.testing.assert_array_equal(out[("sssp", 0)], fresh_eval(view, "sssp", 0))


def test_stream_cache_divergence_eviction():
    """A watcher whose log slid ≥ a window past its view is dead weight."""
    log_a, pending_a = make_log(seed=19)
    log_b, _ = make_log(seed=20)
    view_a = WindowView(log_a, size=WINDOW)
    view_b = WindowView(log_b, size=WINDOW)
    qb = QueryBatcher()
    qb.watch(view_a, "sssp", 0)
    qb.watch(view_b, "bfs", 1)
    # the log moves on without view_a being served (appends only)
    for d in pending_a:
        log_a.append_snapshot(*d)
    assert log_a.num_snapshots - view_a.stop >= view_a.size
    qb.watch(view_b, "bfs", 1)  # housekeeping evicts the diverged watcher
    info = qb.cache_info()
    assert info.evictions == 1
    assert {sq.view for sq in qb.watching()} == {view_b}
    # re-watching the diverged view re-primes cleanly at the current window
    sq = qb.watch(view_a, "sssp", 0)
    out = qb.advance_window(view_a)
    np.testing.assert_array_equal(
        out[("sssp", 0)], fresh_eval(view_a, "sssp", 0)
    )
    assert sq.view is view_a


def test_log_from_stream_roundtrip():
    base, deltas = make_stream(seed=6)
    log = SnapshotLog.from_stream(base, deltas, V)
    assert log.num_snapshots == len(deltas) + 1
    view = WindowView(log)  # whole-log window
    from repro.graph.structures import build_evolving_graph

    ref = build_evolving_graph(*base, deltas, V)
    mat = view.materialize(pad_to_capacity=False)
    # same universe (the log keeps every edge ever seen; so does build_*)
    assert mat.num_snapshots == ref.num_snapshots
    np.testing.assert_array_equal(
        np.asarray(mat.presence_dense()).sum(axis=1),
        np.asarray(ref.presence_dense()).sum(axis=1),
    )
    res = EvolvingQuery(mat, "sssp", 0).evaluate("cqrs")
    np.testing.assert_array_equal(
        res, EvolvingQuery(ref, "sssp", 0).evaluate("cqrs")
    )

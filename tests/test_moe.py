"""MoE dispatch correctness: grouped vs global, capacity behavior."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import TransformerConfig, moe_defs, moe_fwd
from repro.models.params import init_params

BASE = TransformerConfig(
    name="moe-test", num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
    head_dim=16, d_ff=64, vocab_size=64, moe=True, num_experts=8,
    num_shared_experts=0, top_k=2, moe_d_ff=16,
    capacity_factor=8.0,  # high: no drops → groupings must agree exactly
)


def test_grouped_dispatch_matches_global():
    params = init_params(moe_defs(BASE), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.bfloat16)
    y1, aux1 = moe_fwd(BASE, params, x)
    for g in (2, 4):
        cfg = dataclasses.replace(BASE, moe_groups=g)
        yg, auxg = moe_fwd(cfg, params, x)
        np.testing.assert_allclose(
            np.asarray(y1, np.float32), np.asarray(yg, np.float32),
            rtol=2e-2, atol=2e-2,
        )
        np.testing.assert_allclose(float(aux1), float(auxg), rtol=1e-5)


def test_capacity_drops_tokens():
    cfg = dataclasses.replace(BASE, capacity_factor=0.25)
    params = init_params(moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.bfloat16)
    y, _ = moe_fwd(cfg, params, x)  # must run and stay finite with drops
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


def test_moe_grad_flows_through_grouped_dispatch():
    cfg = dataclasses.replace(BASE, moe_groups=4)
    params = init_params(moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.bfloat16)

    def loss(p):
        y, aux = moe_fwd(cfg, p, x)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
    # router must receive gradient via the aux loss
    assert float(jnp.abs(g["router"]).sum()) > 0

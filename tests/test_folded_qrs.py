"""Beyond-paper folded-CQRS (§Perf A): correctness + reduction properties."""
from __future__ import annotations

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.baselines import BASELINES, _prepare_qrs
from repro.core.qrs import fold_qrs
from repro.core.semiring import SEMIRINGS
from conftest import make_evolving


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
def test_folded_cqrs_matches_full(name):
    eg = make_evolving(num_vertices=64, num_edges=256, num_snapshots=6, batch_size=24)
    sr = SEMIRINGS[name]
    ref, _ = BASELINES["full"](eg, sr, 0)
    got, stats = BASELINES["cqrs_folded"](eg, sr, 0)
    np.testing.assert_allclose(got, ref, rtol=1e-6)
    assert stats["num_active"] <= eg.num_vertices
    assert stats["active_edges"] <= stats["qrs_edges"]


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    snaps=st.integers(2, 8),
    name=st.sampled_from(sorted(SEMIRINGS)),
)
def test_folded_cqrs_fuzz(seed, snaps, name):
    eg = make_evolving(num_vertices=48, num_edges=200, num_snapshots=snaps,
                       batch_size=20, seed=seed, readd_prob=0.4)
    sr = SEMIRINGS[name]
    ref, _ = BASELINES["full"](eg, sr, seed % 48)
    got, _ = BASELINES["cqrs_folded"](eg, sr, seed % 48)
    np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_fold_reduces_iterated_work():
    """The active subgraph must be strictly smaller than the QRS whenever
    UVVs exist with outgoing edges (the common case)."""
    eg = make_evolving(num_vertices=256, num_edges=1500, num_snapshots=8,
                       batch_size=30)
    sr = SEMIRINGS["sssp"]
    _, qrs = _prepare_qrs(eg, sr, 0)
    folded = fold_qrs(qrs, sr)
    sd = folded.stats_dict
    assert sd["folded_edges"] > 0
    assert sd["active_edges"] + sd["folded_edges"] == sd["qrs_edges"]
    assert sd["frac_active_vertices"] < 1.0
    # expansion covers every vertex exactly once
    import numpy as np
    ids = np.asarray(folded.active_ids)
    real = ids[ids >= 0]
    assert len(np.unique(real)) == len(real)
    uvv = np.asarray(folded.uvv)
    assert len(real) + uvv.sum() == eg.num_vertices

"""Batched Q×S×V engine: cross-engine equivalence + façade/serving behavior.

The contract under test: ``evaluate_batch`` over Q sources matches Q
independent ``EvolvingQuery.evaluate`` runs **bit-for-bit** (not allclose),
for every registered semiring, on both an RMAT fixture and a path graph, for
both the flat-XLA and the Pallas/ELL engines.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.api import EvolvingQuery, MultiQuery
from repro.core.baselines import BASELINES, run_cqrs_batch
from repro.core.semiring import SEMIRINGS
from repro.graph.structures import build_evolving_graph
from repro.serving.scheduler import QueryBatcher
from conftest import make_evolving


def make_path_graph(n=40, num_snapshots=5):
    """Evolving path 0→1→…→n-1 whose tail edges churn across snapshots."""
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    w = 1.0 + (np.arange(n - 1) % 7).astype(np.float64)
    deltas = []
    cut = n // 2
    for i in range(num_snapshots - 1):
        if i % 2 == 0:  # delete one mid-path edge → tail unreachable
            deltas.append(([], [], [], [cut], [cut + 1]))
        else:  # re-add it
            deltas.append(([cut], [cut + 1], [w[cut]], [], []))
    return build_evolving_graph(src, dst, w, deltas, n)


RMAT = make_evolving(num_vertices=64, num_edges=256, num_snapshots=6, batch_size=24)
PATH = make_path_graph()
SOURCES = [0, 3, 17, 33]


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
@pytest.mark.parametrize("graph_name", ["rmat", "path"])
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_batch_matches_independent_evaluates(name, graph_name, method):
    eg = {"rmat": RMAT, "path": PATH}[graph_name]
    ref = np.stack(
        [EvolvingQuery(eg, name, s).evaluate("cqrs") for s in SOURCES]
    )
    q = EvolvingQuery(eg, name, SOURCES[0])
    got = q.evaluate_batch(SOURCES, method=method)
    assert got.shape == (len(SOURCES), eg.num_snapshots, eg.num_vertices)
    np.testing.assert_array_equal(got, ref, err_msg=f"{method}/{name}/{graph_name}")
    assert q.stats["num_queries"] == len(SOURCES)


def test_batch_matches_full_recompute():
    sr_names = ["sssp", "sswp"]
    for name in sr_names:
        ref = np.stack(
            [BASELINES["full"](RMAT, SEMIRINGS[name], s)[0] for s in SOURCES]
        )
        got, _ = run_cqrs_batch(RMAT, SEMIRINGS[name], SOURCES)
        np.testing.assert_allclose(got, ref, rtol=1e-6)


def test_evaluate_batch_loop_fallback_matches():
    got = EvolvingQuery(RMAT, "sssp", 0).evaluate_batch(SOURCES, method="kickstarter")
    ref = np.stack(
        [BASELINES["kickstarter"](RMAT, SEMIRINGS["sssp"], s)[0] for s in SOURCES]
    )
    np.testing.assert_array_equal(got, ref)


def test_multi_query_facade():
    mq = MultiQuery(RMAT, "bfs", SOURCES)
    res = mq.evaluate()
    assert res.shape == (len(SOURCES), RMAT.num_snapshots, RMAT.num_vertices)
    for i, s in enumerate(SOURCES):
        np.testing.assert_array_equal(mq.result_for(s), res[i])
    assert mq.stats["qrs_edges"] >= 0
    with pytest.raises(ValueError):
        MultiQuery(RMAT, "bfs", [])


def test_multi_query_snapshot_window():
    window = [1, 3, 4]
    mq = MultiQuery(RMAT, "sssp", SOURCES, snapshots=window)
    res = mq.evaluate()
    full = MultiQuery(RMAT, "sssp", SOURCES).evaluate()
    np.testing.assert_array_equal(res, full[:, window, :])


def test_query_batcher_coalesces_and_matches():
    qb = QueryBatcher(max_batch=3)
    reqs = [qb.submit(RMAT, "sssp", s) for s in SOURCES]  # one group, 2 chunks
    reqs += [qb.submit(RMAT, "bfs", 2)]  # second group
    assert qb.pending() == len(SOURCES) + 1
    done = qb.flush()
    assert qb.pending() == 0
    assert [r.uid for r in done] == [r.uid for r in reqs]
    for r in done[: len(SOURCES)]:
        assert r.done
        ref = EvolvingQuery(RMAT, "sssp", r.source).evaluate("cqrs")
        np.testing.assert_array_equal(r.result, ref)
    assert done[0].stats["batched_queries"] == 3  # max_batch chunking
    ref_bfs = EvolvingQuery(RMAT, "bfs", 2).evaluate("cqrs")
    np.testing.assert_array_equal(done[-1].result, ref_bfs)


def test_query_batcher_dedups_sources():
    qb = QueryBatcher(max_batch=8)
    a = qb.submit(RMAT, "sssp", 5)
    b = qb.submit(RMAT, "sssp", 5)
    qb.flush()
    np.testing.assert_array_equal(a.result, b.result)
    assert a.stats["batched_queries"] == 1
    # results are per-request copies, not views pinning the (Q, S, V) batch
    assert a.result.base is None


def test_query_batcher_dedups_before_chunking():
    # 6 requests over 2 unique sources with max_batch=2 → ONE launch
    qb = QueryBatcher(max_batch=2)
    reqs = [qb.submit(RMAT, "sssp", s) for s in [3, 9, 3, 9, 3, 9]]
    qb.flush()
    assert all(r.done for r in reqs)
    assert all(r.stats["batched_queries"] == 2 for r in reqs)
    ref = EvolvingQuery(RMAT, "sssp", 3).evaluate("cqrs")
    np.testing.assert_array_equal(reqs[2].result, ref)


def test_query_batcher_requeues_on_failure():
    qb = QueryBatcher(method="not-a-method")
    reqs = [qb.submit(RMAT, "sssp", 0), qb.submit(RMAT, "bfs", 1)]
    with pytest.raises(KeyError):
        qb.flush()
    # nothing silently dropped: unfinished requests are back in the queue
    assert qb.pending() == len(reqs)
    assert not any(r.done for r in reqs)
    qb.method = "cqrs"
    done = qb.flush()
    assert sorted(r.uid for r in done) == sorted(r.uid for r in reqs)
    assert all(r.done for r in reqs)

"""DLRM smoke tests (tiny tables) + retrieval scoring."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.recsys import recsys_batch, retrieval_batch
from repro.models.dlrm import DLRMConfig, dlrm_defs, dlrm_forward, dlrm_loss, dlrm_retrieval_scores
from repro.models.params import init_params

SMALL = DLRMConfig(
    name="dlrm-smoke",
    table_sizes=(50, 17, 100, 3, 20, 9, 40, 11, 5, 30, 60, 8, 4, 12, 7, 25,
                 13, 6, 19, 33, 21, 14, 10, 16, 22, 18),
    bot_mlp=(13, 64, 32),
    top_mlp=(64, 32, 1),
    embed_dim=32,
)


def test_dlrm_forward_and_loss():
    params = init_params(dlrm_defs(SMALL), jax.random.PRNGKey(0))
    batch = recsys_batch(SMALL, 16, seed=0)
    logits = jax.jit(lambda p, b: dlrm_forward(SMALL, p, b))(params, batch)
    assert logits.shape == (16,)
    (loss, metrics), g = jax.value_and_grad(
        lambda p: dlrm_loss(SMALL, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree_util.tree_leaves(g))


def test_dlrm_retrieval():
    params = init_params(dlrm_defs(SMALL), jax.random.PRNGKey(1))
    batch = retrieval_batch(SMALL, 500, seed=1)
    scores, ids = jax.jit(
        lambda p, b: dlrm_retrieval_scores(SMALL, p, b, top_k=10)
    )(params, batch)
    assert scores.shape == (10,) and ids.shape == (10,)
    # top-k really is the max of the full scoring
    full = np.asarray(
        dlrm_retrieval_scores(SMALL, params, batch, top_k=500)[0]
    )
    np.testing.assert_allclose(np.asarray(scores), np.sort(full)[::-1][:10], rtol=1e-6)


def test_dlrm_interaction_count():
    assert SMALL.n_interactions == 27 * 26 // 2
    assert SMALL.total_rows == sum(SMALL.table_sizes)

"""vrelax Pallas kernel vs ref.py oracle + kernel-backed CQRS equivalence."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.baselines import run_full
from repro.core.bounds import compute_bounds
from repro.core.qrs import build_qrs
from repro.core.semiring import SEMIRINGS
from repro.graph.ell import pack_ell
from repro.kernels.vrelax.kernel import vrelax_partial_pallas
from repro.kernels.vrelax.ops import build_presence_ell, concurrent_fixpoint_ell
from repro.kernels.vrelax.ref import vrelax_partial_ref
from conftest import make_evolving


def _rand_inputs(rng, s, r, d, w_words):
    gathered = jnp.asarray(rng.uniform(0.0, 50.0, (s, r, d)).astype(np.float32))
    weights = jnp.asarray(rng.uniform(0.5, 8.0, (r, d)).astype(np.float32))
    words = jnp.asarray(rng.integers(0, 2**32, (r, d, w_words), dtype=np.uint64).astype(np.uint32))
    return gathered, weights, words


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
@pytest.mark.parametrize("s,r,d", [(8, 8, 128), (16, 32, 128), (64, 8, 256)])
def test_vrelax_kernel_matches_ref(name, s, r, d):
    rng = np.random.default_rng(0)
    gathered, weights, words = _rand_inputs(rng, s, r, d, (s + 31) // 32)
    got = vrelax_partial_pallas(gathered, weights, words, semiring=name, interpret=True)
    ref = vrelax_partial_ref(gathered, weights, words, semiring=name)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    s_blocks=st.integers(1, 5),
    r_blocks=st.integers(1, 4),
    name=st.sampled_from(sorted(SEMIRINGS)),
)
def test_vrelax_kernel_fuzz(seed, s_blocks, r_blocks, name):
    rng = np.random.default_rng(seed)
    s, r = 8 * s_blocks, 8 * r_blocks
    gathered, weights, words = _rand_inputs(rng, s, r, 128, (s + 31) // 32)
    got = vrelax_partial_pallas(gathered, weights, words, semiring=name, interpret=True)
    ref = vrelax_partial_ref(gathered, weights, words, semiring=name)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


def test_vrelax_identity_for_absent_edges():
    """All-zero presence words must reduce to the semiring identity."""
    for name, sr in SEMIRINGS.items():
        gathered = jnp.ones((8, 8, 128), jnp.float32)
        weights = jnp.ones((8, 128), jnp.float32)
        words = jnp.zeros((8, 128, 1), jnp.uint32)
        got = vrelax_partial_pallas(gathered, weights, words, semiring=name, interpret=True)
        np.testing.assert_allclose(np.asarray(got), sr.identity)


@pytest.mark.parametrize("name", ["sssp", "sswp"])
def test_kernel_backed_cqrs_equals_full(name):
    """End-to-end: kernel CQRS == per-snapshot full recompute."""
    eg = make_evolving(num_vertices=48, num_edges=200, num_snapshots=6, batch_size=16)
    sr = SEMIRINGS[name]
    ref, _ = run_full(eg, sr, 0)

    bounds = compute_bounds(eg, sr, 0)
    qrs = build_qrs(eg, bounds.uvv, bounds.val_cap, sr)
    ell = pack_ell(
        np.asarray(qrs.src)[np.asarray(qrs.valid)],
        np.asarray(qrs.dst)[np.asarray(qrs.valid)],
        np.asarray(qrs.weight)[np.asarray(qrs.valid)],
        eg.num_vertices,
        slot_width=128,
    )
    presence_ell = build_presence_ell(
        jnp.asarray(np.asarray(qrs.presence)[np.asarray(qrs.valid)]), ell
    )
    vals, _ = concurrent_fixpoint_ell(
        qrs.bootstrap, ell, presence_ell, sr, eg.num_vertices, eg.num_snapshots,
        interpret=True,
    )
    np.testing.assert_allclose(np.asarray(vals), ref, rtol=1e-6)

"""Warm-start serving: streaming-state checkpoints proven by crash recovery.

The contract under test (see ``repro.checkpoint.streamstate``): a replica
killed at ANY slide boundary and restored from its checkpoint serves exactly
the same float arrays as the uninterrupted stream — monotone fixpoints are
unique, so the checkpointed ``val_cap``/``val_cup`` *are* the replayed
window's fixpoints and restore injects them instead of cold-solving.  The
restore is elastic (single-host ↔ sharded, any shard count) because the
payload is in global vertex terms and min/max segment reductions are
order-exact.

Covered here:

* kill-and-restore at EVERY slide boundary of a churn stream, 3 semirings ×
  both engines, single-host scalar path through the ``CheckpointManager``
  disk roundtrip;
* the same bit-for-bit property across a log capacity-growth repack and a
  mid-stream ``remove_source`` on the batched path;
* elastic restore in all directions on the in-process 1-shard SPMD path
  (sharded→sharded, sharded→single-host, single-host→sharded);
* ``ServeSupervisor`` crash recovery: checkpoint every k slides, injected
  failure, restart (optionally onto a different shard count), delta-replay
  catch-up, heartbeat wiring;
* ``QueryBatcher`` warm-state checkpoints (shared window + per-group
  payloads + watcher registry, incl. quarantined lanes);
* ``CheckpointManager`` regressions: orphaned ``step_*.tmp`` sweep after a
  crash between array write and rename, and ``keep``-pruning never deleting
  a step a concurrent ``load()`` resolved;
* a seed-swept property over (seed, semiring, engine, kill point) via the
  ``_prop`` shim.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, resume_streaming, streaming_state
from repro.core.api import StreamingQuery, StreamingQueryBatch
from repro.ft import HeartbeatMonitor, ServeSupervisor
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.stream import SnapshotLog, WindowView
from _prop import given, settings, st

V = 48
WINDOW = 3
SOURCES = [0, 7, 13, 21]


def make_stream(seed: int, *, num_snapshots: int = WINDOW + 4, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def build_replica(seed: int, query: str, method: str, *, n_shards: int = 0,
                  batch: bool = False, capacity: int = 512, source: int = 0):
    """Primed-log replica + the deltas still pending; sharded when asked."""
    base, deltas = make_stream(seed)
    if n_shards:
        from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView

        log = ShardedSnapshotLog(V, n_shards, capacity=64)
        mk_view = ShardedWindowView
    else:
        log = SnapshotLog(V, capacity=capacity)
        mk_view = WindowView
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    view = mk_view(log, size=WINDOW)
    if batch:
        sq = StreamingQueryBatch(view, query, SOURCES, method=method)
    else:
        sq = StreamingQuery(view, query, source, method=method)
    return sq, deltas[WINDOW - 1:]


def serve(sq, deltas) -> list:
    out = [np.asarray(sq.results).copy()]
    for d in deltas:
        sq.advance(d)
        out.append(np.asarray(sq.results).copy())
    return out


# ===================================================================== kill
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
@pytest.mark.parametrize("query", ["sssp", "sswp", "ssnp"])
def test_kill_and_restore_at_every_slide_boundary(tmp_path, query, method):
    """Restore at EVERY slide boundary is bit-for-bit equal to the
    uninterrupted stream — including every slide served after catch-up —
    through a real CheckpointManager disk roundtrip."""
    ref_sq, pending = build_replica(0, query, method)
    ref = serve(ref_sq, pending)  # ref[j] = results after j slides
    mgr = CheckpointManager(str(tmp_path / f"{query}-{method}"), keep=0)
    for kill in range(len(pending) + 1):
        sq, pend = build_replica(0, query, method)
        sq.results
        for d in pend[:kill]:
            sq.advance(d)
        tree, extra = sq.checkpoint_state()
        mgr.save(kill, tree, extra=extra)
        arrays, manifest = mgr.load(step=kill)
        restored = StreamingQuery.resume(arrays, manifest["extra"])
        assert restored.stats["resumed"], "restore must not cold-solve"
        np.testing.assert_array_equal(
            np.asarray(restored.results), ref[kill],
            err_msg=f"restore at slide {kill} not bit-for-bit",
        )
        for j, d in enumerate(pend[kill:], start=kill):
            restored.advance(d)
            np.testing.assert_array_equal(
                np.asarray(restored.results), ref[j + 1],
                err_msg=f"catch-up slide {j} after restore-at-{kill} diverged",
            )


def test_restore_across_capacity_growth_repack():
    """Checkpoint BEFORE a log capacity doubling (and the ELL/QRS repack it
    forces), restore, then drive the restored replica across the growth —
    still bit-for-bit with the uninterrupted stream."""
    sq, pending = build_replica(1, "sssp", "cqrs_ell", capacity=64)
    ref_sq, _ = build_replica(1, "sssp", "cqrs_ell", capacity=64)
    # a dense fresh-edge delta that must overflow the log's capacity class
    log = sq.view.log
    have = set(zip(log.src[: log.num_edges].tolist(),
                   log.dst[: log.num_edges].tolist()))
    need = log.capacity - log.num_edges + 1
    fresh = [(s, d) for s in range(V) for d in range(V)
             if s != d and (s, d) not in have][:need]
    grow = ([s for s, _ in fresh], [d for _, d in fresh],
            [1.0 + 0.25 * i for i in range(need)], [], [])
    script = [pending[0], grow] + pending[1:3]

    cap0 = log.capacity
    sq.results
    sq.advance(script[0])
    tree, extra = streaming_state(sq)

    ref = serve(ref_sq, script)
    restored = resume_streaming(tree, extra)
    np.testing.assert_array_equal(np.asarray(restored.results), ref[1])
    for j, d in enumerate(script[1:], start=1):
        restored.advance(d)
        np.testing.assert_array_equal(
            np.asarray(restored.results), ref[j + 1],
            err_msg=f"slide {j} across capacity growth diverged",
        )
    assert restored.view.log.capacity > cap0, "growth never happened"


def test_restore_across_mid_stream_remove_source():
    """Batched path: checkpoint, then the restored replica (and the
    reference) drop a lane mid-stream — remove_source on resumed state must
    behave exactly like on never-interrupted state."""
    sq, pending = build_replica(2, "sswp", "cqrs", batch=True)
    ref_sq, _ = build_replica(2, "sswp", "cqrs", batch=True)

    sq.results
    ref_sq.results
    sq.advance(pending[0])
    ref_sq.advance(pending[0])
    tree, extra = streaming_state(sq)
    restored = resume_streaming(tree, extra)
    np.testing.assert_array_equal(
        np.asarray(restored.results), np.asarray(ref_sq.results)
    )
    for r in (restored, ref_sq):
        r.remove_source(7)
    assert restored.sources == ref_sq.sources
    np.testing.assert_array_equal(
        np.asarray(restored.results), np.asarray(ref_sq.results)
    )
    for d in pending[1:]:
        restored.advance(d)
        ref_sq.advance(d)
        np.testing.assert_array_equal(
            np.asarray(restored.results), np.asarray(ref_sq.results)
        )


def test_restore_after_remove_source_checkpoint():
    """The dual: remove a lane, THEN checkpoint — the payload captures the
    shrunken lane set and restores it (padded lane classes re-entered)."""
    sq, pending = build_replica(3, "ssnp", "cqrs_ell", batch=True)
    ref_sq, _ = build_replica(3, "ssnp", "cqrs_ell", batch=True)
    for r in (sq, ref_sq):
        r.results
        r.advance(pending[0])
        r.remove_source(13)
        r.advance(pending[1])
    tree, extra = streaming_state(sq)
    restored = resume_streaming(tree, extra)
    assert restored.sources == ref_sq.sources
    np.testing.assert_array_equal(
        np.asarray(restored.results), np.asarray(ref_sq.results)
    )
    for d in pending[2:]:
        restored.advance(d)
        ref_sq.advance(d)
        np.testing.assert_array_equal(
            np.asarray(restored.results), np.asarray(ref_sq.results)
        )


# ================================================================== elastic
@pytest.mark.parametrize("src_shards,dst_shards", [(1, 1), (1, 0), (0, 1)])
def test_elastic_restore_directions(src_shards, dst_shards):
    """Checkpoints are shard-layout independent: a replica checkpointed on
    ``src_shards`` restores onto ``dst_shards`` (0 = single host) and keeps
    serving bit-for-bit.  The 1-shard SPMD path is a real shard_map on the
    lone CPU device, so tier-1 exercises the elastic machinery in-process
    (the 8-device multi-count variant lives in _stream_shard_checks.py)."""
    sq, pending = build_replica(4, "sssp", "cqrs", n_shards=src_shards)
    ref_sq, _ = build_replica(4, "sssp", "cqrs", n_shards=src_shards)
    ref = serve(ref_sq, pending)
    sq.results
    sq.advance(pending[0])
    sq.advance(pending[1])
    tree, extra = streaming_state(sq)
    restored = resume_streaming(tree, extra, n_shards=dst_shards)
    if dst_shards:
        from repro.distributed.stream_shard import ShardedStreamingQuery

        assert isinstance(restored, ShardedStreamingQuery)
    else:
        assert type(restored) is StreamingQuery
    np.testing.assert_array_equal(np.asarray(restored.results), ref[2])
    for j, d in enumerate(pending[2:], start=2):
        restored.advance(d)
        np.testing.assert_array_equal(
            np.asarray(restored.results), ref[j + 1],
            err_msg=f"{src_shards}->{dst_shards} shards slide {j}",
        )


def test_elastic_restore_sharded_batch_ell():
    """Batched cqrs_ell on the 1-shard SPMD path roundtrips both ways."""
    sq, pending = build_replica(5, "sssp", "cqrs_ell", n_shards=1, batch=True)
    ref_sq, _ = build_replica(5, "sssp", "cqrs_ell", n_shards=1, batch=True)
    ref = serve(ref_sq, pending)
    sq.results
    sq.advance(pending[0])
    tree, extra = streaming_state(sq)
    for n in (1, 0):
        restored = resume_streaming(tree, extra, n_shards=n)
        np.testing.assert_array_equal(np.asarray(restored.results), ref[1])
        for j, d in enumerate(pending[1:], start=1):
            restored.advance(d)
            np.testing.assert_array_equal(
                np.asarray(restored.results), ref[j + 1],
                err_msg=f"->{n} shards slide {j}",
            )


# =============================================================== supervisor
def test_supervisor_recovers_from_injected_crash(tmp_path, monkeypatch):
    """Kill the replica mid-stream: the supervisor restores the latest
    committed checkpoint, catches up by delta replay, and every served
    slide — including the re-served ones — is bit-for-bit."""
    ref_sq, pending = build_replica(0, "sssp", "cqrs")
    ref = serve(ref_sq, pending)

    sq, _ = build_replica(0, "sssp", "cqrs")
    calls = {"n": 0}
    orig = StreamingQuery.advance

    def chaos(self, delta=None):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected preemption")
        return orig(self, delta)

    monkeypatch.setattr(StreamingQuery, "advance", chaos)
    beats = HeartbeatMonitor(num_workers=1)
    sup = ServeSupervisor(
        CheckpointManager(str(tmp_path)), ckpt_every=2, heartbeat=beats
    )
    replica, served, stats = sup.run(sq, pending)
    assert stats["restarts"] == 1
    assert stats["slides_served"] == len(pending)
    assert replica is not sq  # restarted into a fresh object
    for j, (got, want) in enumerate(zip(served, ref[1:])):
        np.testing.assert_array_equal(got, want, err_msg=f"slide {j}")
    assert not beats.dead_workers()


def test_supervisor_elastic_restart_onto_different_shard_count(tmp_path,
                                                               monkeypatch):
    """After the crash the replica is rebuilt on a DIFFERENT shard count
    (single host → 1-shard SPMD) and the re-served slides still match."""
    from repro.distributed.stream_shard import ShardedStreamingQuery

    ref_sq, pending = build_replica(6, "sswp", "cqrs")
    ref = serve(ref_sq, pending)
    sq, _ = build_replica(6, "sswp", "cqrs")
    calls = {"n": 0}
    orig = StreamingQuery.advance

    def chaos(self, delta=None):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("injected preemption")
        return orig(self, delta)

    monkeypatch.setattr(StreamingQuery, "advance", chaos)
    sup = ServeSupervisor(CheckpointManager(str(tmp_path)), ckpt_every=1)
    replica, served, stats = sup.run(sq, pending, n_shards=1)
    assert stats["restarts"] == 1
    assert isinstance(replica, ShardedStreamingQuery)
    for j, (got, want) in enumerate(zip(served, ref[1:])):
        np.testing.assert_array_equal(got, want, err_msg=f"slide {j}")


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    sq, pending = build_replica(0, "sssp", "cqrs")

    class Always(Exception):
        pass

    def boom(self, delta=None):
        raise Always()

    sup = ServeSupervisor(mgr, ckpt_every=1, max_restarts=2)
    sq.advance = boom.__get__(sq)
    with pytest.raises(Always):
        # every restored replica is re-broken, so the budget must bound it
        sup.run(sq, pending, on_restore=lambda r, s: setattr(
            r, "advance", boom.__get__(r)))


# ============================================================ query batcher
def _build_batcher(seed: int):
    base, deltas = make_stream(seed)
    log = SnapshotLog(V, capacity=512)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    view = WindowView(log, size=WINDOW)
    from repro.serving.scheduler import QueryBatcher

    qb = QueryBatcher()
    for q in ("sssp", "sswp"):
        for s in (0, 7, 13):
            qb.watch(view, q, s)
    return qb, view, deltas[WINDOW - 1:]


def test_batcher_checkpoint_roundtrip(tmp_path):
    """The whole warm serving state — shared window, every (query, method)
    group, the watcher registry — survives a manager roundtrip and keeps
    serving bit-for-bit (keys re-built against the NEW view identity)."""
    from repro.serving.scheduler import QueryBatcher

    qb_ref, view_ref, pending = _build_batcher(7)
    ref = [qb_ref.advance_window(view_ref, d) for d in pending]

    qb, view, _ = _build_batcher(7)
    for d in pending[:2]:
        qb.advance_window(view, d)
    tree, extra = qb.checkpoint_state(view)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, tree, extra=extra)
    arrays, manifest = mgr.load()
    qb2, view2 = QueryBatcher.resume(arrays, manifest["extra"])
    assert len(qb2.watching(view2)) == 6
    for k, d in enumerate(pending[2:], start=2):
        got = qb2.advance_window(view2, d)
        assert set(got) == set(ref[k])
        for key in ref[k]:
            np.testing.assert_array_equal(got[key], ref[k][key],
                                          err_msg=str(key))


def test_batcher_resume_elastic_and_quarantine(tmp_path):
    """Elastic batcher restore (→ 1-shard SPMD) plus quarantine flags:
    a quarantined lane resumes into its own dedicated group."""
    from repro.serving.scheduler import QueryBatcher

    qb_ref, view_ref, pending = _build_batcher(8)
    ref = [qb_ref.advance_window(view_ref, d) for d in pending]

    qb, view, _ = _build_batcher(8)
    qb.advance_window(view, pending[0])
    # force one lane into quarantine by hand (the QoS path is covered in
    # test_stream_pipeline; here we pin that the FLAG survives the roundtrip)
    key = next(k for k in qb._streams if k[1] == "sssp" and k[2] == 7)
    entry = qb._streams[key]
    batch = entry.sq.batch
    batch.remove_source(7)
    solo = StreamingQueryBatch(view, "sssp", [7], method=entry.sq.method)
    solo.results
    gkey = (id(view), "sssp", entry.sq.method, "q", 7)
    qb._batches[gkey] = solo
    entry.sq.batch = solo
    entry.gkey = gkey
    entry.quarantined = True

    qb.advance_window(view, pending[1])
    tree, extra = qb.checkpoint_state(view)
    assert any(w["quarantined"] for w in extra["watchers"])
    qb2, view2 = QueryBatcher.resume(tree, extra, n_shards=1)
    assert ("sssp", 7) in qb2.quarantined()
    for k, d in enumerate(pending[2:], start=2):
        got = qb2.advance_window(view2, d)
        for key2 in ref[k]:
            np.testing.assert_array_equal(got[key2], ref[k][key2],
                                          err_msg=str(key2))


# ================================================== checkpoint-manager fixes
def _crashing_rename(monkeypatch, times: int = 1):
    """os.rename that dies on the first ``times`` checkpoint commits —
    i.e. AFTER arrays.npz + manifest.json are written, BEFORE the atomic
    rename publishes the step."""
    real = os.rename
    state = {"left": times}

    def boom(src, dst):
        if state["left"] > 0 and str(src).endswith(".tmp"):
            state["left"] -= 1
            raise OSError("injected crash between array write and rename")
        return real(src, dst)

    monkeypatch.setattr(os, "rename", boom)
    return state


def test_crash_between_write_and_rename_stays_invisible(tmp_path, monkeypatch):
    """A crash after the array write but before the rename must leave the
    previous committed step untouched and the torn write invisible."""
    mgr = CheckpointManager(str(tmp_path))
    sq, pending = build_replica(0, "sssp", "cqrs")
    sq.results
    tree, extra = streaming_state(sq)
    mgr.save(0, tree, extra=extra)
    sq.advance(pending[0])
    tree1, extra1 = streaming_state(sq)
    _crashing_rename(monkeypatch)
    with pytest.raises(OSError):
        mgr.save(1, tree1, extra=extra1)
    # torn write is invisible; the orphan .tmp is on disk awaiting sweep
    assert mgr.latest_step() == 0
    assert any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))
    arrays, manifest = mgr.load()
    restored = resume_streaming(arrays, manifest["extra"])
    np.testing.assert_array_equal(
        np.asarray(restored.results),
        np.asarray(resume_streaming(tree, extra).results),
    )


def test_startup_sweeps_orphaned_tmp_dirs(tmp_path, monkeypatch):
    """Restart after the torn write: the new manager sweeps ``step_*.tmp``
    orphans at startup and the next save of the same step commits clean."""
    mgr = CheckpointManager(str(tmp_path))
    sq, _ = build_replica(0, "sssp", "cqrs")
    sq.results
    tree, extra = streaming_state(sq)
    _crashing_rename(monkeypatch)
    with pytest.raises(OSError):
        mgr.save(0, tree, extra=extra)
    assert any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))
    mgr2 = CheckpointManager(str(tmp_path))  # the restarted process
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))
    assert mgr2.latest_step() is None
    mgr2.save(0, tree, extra=extra)
    assert mgr2.latest_step() == 0


def test_gc_never_prunes_a_step_a_reader_resolved(tmp_path):
    """``keep``-pruning must not delete the step a concurrent ``load()``
    just resolved, even when newer saves land while the reader holds it."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    sq, pending = build_replica(0, "sssp", "cqrs")
    sq.results
    tree, extra = streaming_state(sq)
    mgr.save(1, tree, extra=extra)
    arrays, manifest = mgr.load(step=1)  # reader resolves step 1
    for step in (2, 3, 4):
        sq.advance(pending[step - 2])
        tree, extra = streaming_state(sq)
        mgr.save(step, tree, extra=extra)  # gc runs with keep=1
    assert os.path.isdir(str(tmp_path / "step_000000001")), \
        "gc deleted the step a concurrent load() resolved"
    assert not os.path.isdir(str(tmp_path / "step_000000003")), \
        "unprotected steps past keep must still be pruned"
    # and the pinned step is still fully readable
    restored = resume_streaming(arrays, manifest["extra"])
    assert np.asarray(restored.results).shape == (WINDOW, V)


# ================================================================= property
@settings(max_examples=4)
@given(
    seed=st.integers(0, 10_000),
    query=st.sampled_from(["sssp", "sswp", "ssnp"]),
    method=st.sampled_from(["cqrs", "cqrs_ell"]),
    kill=st.integers(0, 4),
)
def test_kill_restore_property(seed, query, method, kill):
    """Seed-swept kill/restore: any stream, any semiring, either engine,
    any kill point — restore + catch-up is bit-for-bit."""
    ref_sq, pending = build_replica(seed, query, method)
    ref = serve(ref_sq, pending)
    sq, pend = build_replica(seed, query, method)
    sq.results
    kill = min(kill, len(pend))
    for d in pend[:kill]:
        sq.advance(d)
    tree, extra = streaming_state(sq)
    restored = resume_streaming(tree, extra)
    np.testing.assert_array_equal(np.asarray(restored.results), ref[kill])
    for j, d in enumerate(pend[kill:], start=kill):
        restored.advance(d)
        np.testing.assert_array_equal(
            np.asarray(restored.results), ref[j + 1],
            err_msg=f"seed={seed} {query}/{method} kill={kill} slide={j}",
        )

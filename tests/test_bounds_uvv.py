"""Property tests for the paper's Theorems 1 and 2 (hypothesis-driven).

Theorem 1 — bound safety:   lower(v) <= Val_i(v) <= upper(v) for all i.
Theorem 2 — UVV soundness:  bounds equal  =>  value identical in every
snapshot (and equal to the bound).

These are the *invariants the whole system rests on*; we fuzz them across
random evolving graphs, all five semirings, and varied churn rates.
"""
from __future__ import annotations

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.baselines import run_full
from repro.core.bounds import compute_bounds
from repro.core.semiring import SEMIRINGS
from conftest import make_evolving


def _check_theorems(eg, name, source=0):
    sr = SEMIRINGS[name]
    b = compute_bounds(eg, sr, source)
    full, _ = run_full(eg, sr, source)  # (S, V) ground truth
    lower = np.asarray(b.lower)
    upper = np.asarray(b.upper)
    uvv = np.asarray(b.uvv)

    # Theorem 1: bounds bracket every snapshot's value (inf-safe comparisons).
    assert (full >= lower[None, :] - 1e-5).all(), "lower bound violated"
    assert (full <= upper[None, :] + 1e-5).all(), "upper bound violated"

    # Theorem 2: UVV vertices have identical values across all snapshots,
    # equal to the bound value.
    if uvv.any():
        vals = full[:, uvv]
        assert np.all(vals == vals[0:1, :]), "UVV vertex value changed"
        ref = np.asarray(b.val_cap)[uvv]
        same = (vals[0] == ref) | (np.isinf(vals[0]) & np.isinf(ref))
        assert same.all(), "UVV value != bound value"
    return uvv


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
def test_theorems_hold_smoke(name):
    eg = make_evolving(num_vertices=48, num_edges=200, num_snapshots=5, batch_size=20)
    uvv = _check_theorems(eg, name)
    # the paper's premise: most vertices are UVVs under gradual churn
    assert uvv.mean() > 0.2


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    v=st.integers(16, 80),
    snaps=st.integers(2, 9),
    batch=st.integers(2, 40),
    name=st.sampled_from(sorted(SEMIRINGS)),
)
def test_theorems_hold_fuzz(seed, v, snaps, batch, name):
    eg = make_evolving(
        num_vertices=v,
        num_edges=min(4 * v, v * (v - 1) // 2),
        num_snapshots=snaps,
        batch_size=batch,
        seed=seed,
        readd_prob=0.4,
    )
    _check_theorems(eg, name, source=seed % v)


def test_uvv_detection_is_accurate():
    """Fig. 10 analog: detected UVVs should cover most true UVVs."""
    eg = make_evolving(num_vertices=128, num_edges=600, num_snapshots=8, batch_size=30)
    sr = SEMIRINGS["sssp"]
    full, _ = run_full(eg, sr, 0)
    true_uvv = np.all(full == full[0:1, :], axis=0)
    detected = np.asarray(compute_bounds(eg, sr, 0).uvv)
    # safety: every detected UVV is a true UVV
    assert (~detected | true_uvv).all()
    # effectiveness: detect the large majority (paper: "nearly all")
    assert detected.sum() >= 0.8 * true_uvv.sum()


# ------------------------------------------------------------------ inf==inf
def test_detect_uvv_inf_equals_inf_regression():
    """Paper's explicit note: mutually-unreachable vertices (identity bound on
    both sides, including ±inf) ARE UVVs — detect_uvv must treat inf == inf
    as equal for both CASMIN (+inf identities) and CASMAX (sswp's +inf
    source / viterbi values) directions."""
    import jax.numpy as jnp

    from repro.core.bounds import detect_uvv

    cap = jnp.asarray([0.0, 3.0, np.inf, -np.inf, np.inf], jnp.float32)
    cup = jnp.asarray([0.0, 2.0, np.inf, -np.inf, 5.0], jnp.float32)
    got = np.asarray(detect_uvv(cap, cup))
    np.testing.assert_array_equal(got, [True, False, True, True, False])


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
def test_unreachable_vertices_are_uvv(name):
    """End-to-end: a vertex with no in-edges in any snapshot sits at the
    identity bound (±inf for CASMIN/ssnp-style queries) on BOTH sides and
    must be flagged UVV for every semiring."""
    from repro.graph.structures import build_evolving_graph

    # path 0→1→2 with a churning tail edge; vertex 3 is isolated forever
    src, dst, w = [0, 1], [1, 2], [2.0, 3.0]
    deltas = [([], [], [], [1], [2]), ([1], [2], [3.0], [], [])]
    eg = build_evolving_graph(src, dst, w, deltas, 4)
    sr = SEMIRINGS[name]
    b = compute_bounds(eg, sr, 0)
    uvv = np.asarray(b.uvv)
    assert uvv[3], f"{name}: isolated vertex not UVV"
    assert np.asarray(b.val_cap)[3] == sr.identity
    if name in ("bfs", "sssp", "ssnp"):  # CASMIN: identity is +inf
        assert np.isinf(np.asarray(b.val_cap)[3])
    if name == "sswp":  # CASMAX: the source itself carries +inf on both sides
        assert np.isinf(np.asarray(b.val_cap)[0]) and uvv[0]

"""Multi-device checks, executed in a subprocess with 8 host devices.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python tests/_distributed_checks.py <check-name>
Prints CHECK_OK on success (asserts otherwise).
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def check_evolve():
    """Distributed CQRS == single-host concurrent engine == full recompute."""
    from conftest import make_evolving
    from repro.core.baselines import run_full
    from repro.core.bounds import compute_bounds
    from repro.core.qrs import build_qrs
    from repro.core.semiring import SEMIRINGS
    from repro.distributed.evolve import (
        distributed_concurrent_fixpoint,
        shard_evolving_arrays,
    )

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sr = SEMIRINGS["sssp"]
    eg = make_evolving(num_vertices=64, num_edges=256, num_snapshots=8, batch_size=20)
    ref, _ = run_full(eg, sr, 0)
    bounds = compute_bounds(eg, sr, 0)
    qrs = build_qrs(eg, bounds.uvv, bounds.val_cap, sr)
    sharded = shard_evolving_arrays(qrs, mesh)
    with mesh:
        vals, iters = distributed_concurrent_fixpoint(
            qrs.bootstrap, sharded, sr, eg.num_vertices, eg.num_snapshots, mesh
        )
    np.testing.assert_allclose(np.asarray(vals), ref, rtol=1e-6)
    print("CHECK_OK")


def check_compressed_psum():
    from jax.experimental.shard_map import shard_map
    from repro.distributed.compression import compressed_psum

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))

    fn = shard_map(
        lambda v: compressed_psum(v[0], "data")[None],
        mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_rep=False,
    )
    got = np.asarray(fn(x))  # every shard returns the same reduced value
    want = np.asarray(x.sum(axis=0))
    for row in got:
        rel = np.abs(row - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.02, rel  # int8: ~1/127 relative error budget
    print("CHECK_OK")


def check_pipeline():
    from repro.distributed.pipeline import gpipe_apply

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    # 2 stages, each applying one linear layer: y = relu(x @ w)
    w = jnp.asarray(rng.normal(size=(2, 16, 16)).astype(np.float32) * 0.3)
    xs = jnp.asarray(rng.normal(size=(4, 8, 16)).astype(np.float32))  # (M, mb, d)

    def stage_fn(p, x):
        return jax.nn.relu(x @ p)

    got = gpipe_apply(stage_fn, w, xs, mesh, axis="pod")
    want = jax.nn.relu(jax.nn.relu(xs @ w[0]) @ w[1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    print("CHECK_OK")


def check_dlrm_sharded_lookup():
    from repro.models.dlrm import embedding_lookup

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, (40,)).astype(np.int32))
    with mesh:
        got = embedding_lookup(table, idx, mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(table[idx]), rtol=1e-6)
    print("CHECK_OK")


def check_lm_spmd_step():
    """Tiny LM train step under pjit on a (2,4) mesh with FSDP rules."""
    from repro.models.layers import TransformerConfig
    from repro.models.params import (
        abstract_params, init_params, param_shardings,
    )
    from repro.models.transformer import transformer_defs
    from repro.optim.adamw import AdamWConfig, adamw_init, opt_state_defs
    from repro.training.steps import build_lm_train_step
    from repro.distributed.partitioning import sharding_for

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = TransformerConfig(
        name="tiny", num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=96, remat=True,
    )
    defs = transformer_defs(cfg)
    pshard = param_shardings(defs, mesh)
    oshard = param_shardings(opt_state_defs(defs), mesh)
    bshard = {
        "tokens": sharding_for(("batch", "seq"), mesh, shape=(8, 16)),
        "targets": sharding_for(("batch", "seq"), mesh, shape=(8, 16)),
    }
    step = build_lm_train_step(cfg, AdamWConfig(peak_lr=1e-3))
    jstep = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1))

    with mesh:
        params = jax.device_put(init_params(defs, jax.random.PRNGKey(0)), pshard)
        opt = jax.device_put(adamw_init(params), oshard)
        batch = jax.device_put(
            {"tokens": jnp.ones((8, 16), jnp.int32),
             "targets": jnp.ones((8, 16), jnp.int32)},
            bshard,
        )
        losses = []
        for _ in range(3):
            params, opt, m = jstep(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[2] < losses[0], losses  # optimizing a constant batch
    print("CHECK_OK")


def check_elastic_checkpoint():
    """Save sharded on a (2,4) mesh, restore onto (8,) and (4,2) — elastic."""
    import tempfile

    from jax.sharding import NamedSharding
    from repro.checkpoint.manager import CheckpointManager

    rng = np.random.default_rng(0)
    tree = {
        "w": jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(32,)).astype(np.float32)),
    }
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    shard_a = {
        "w": NamedSharding(mesh_a, P("data", "model")),
        "b": NamedSharding(mesh_a, P("model")),
    }
    placed = jax.device_put(tree, shard_a)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, placed)
        for shape, axes, specs in (
            ((8,), ("model",), {"w": P(None, "model"), "b": P("model")}),
            ((4, 2), ("data", "model"), {"w": P("model", "data"), "b": P()}),
        ):
            mesh_b = jax.make_mesh(shape, axes)
            shard_b = {k: NamedSharding(mesh_b, v) for k, v in specs.items()}
            restored, manifest = mgr.restore(tree, shardings=shard_b)
            assert manifest["step"] == 1
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
            np.testing.assert_array_equal(np.asarray(restored["b"]), np.asarray(tree["b"]))
            assert restored["w"].sharding == shard_b["w"]
    print("CHECK_OK")


def check_folded_evolve():
    """Distributed folded-CQRS == full recompute (active-subgraph sharding)."""
    from conftest import make_evolving
    from repro.core.baselines import run_full, _prepare_qrs
    from repro.core.qrs import fold_qrs
    from repro.core.semiring import SEMIRINGS
    from repro.distributed.evolve import (
        distributed_concurrent_fixpoint, shard_evolving_arrays,
    )

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sr = SEMIRINGS["sssp"]
    eg = make_evolving(num_vertices=64, num_edges=256, num_snapshots=8, batch_size=20)
    ref, _ = run_full(eg, sr, 0)
    _, qrs = _prepare_qrs(eg, sr, 0)
    folded = fold_qrs(qrs, sr, align=8)  # v_active must divide model=4
    sharded = shard_evolving_arrays(folded, mesh)
    # distributed engine needs a (V_active,) bootstrap per vertex shard; the
    # folded bootstrap is (S, V_active) — use the per-snapshot generalization
    from repro.core.concurrent import concurrent_fixpoint

    vals, _ = concurrent_fixpoint(
        folded.bootstrap, folded.src, folded.dst, folded.weight,
        folded.presence, folded.valid, sr, folded.num_active, eg.num_snapshots,
    )
    got = folded.expand(np.asarray(vals))
    np.testing.assert_allclose(got, ref, rtol=1e-6)
    print("CHECK_OK")


if __name__ == "__main__":
    globals()[f"check_{sys.argv[1]}"]()

"""embedding_bag + ell_agg + flash_attention kernels vs oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.ell_agg.ops import ell_multi_aggregate
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------- embedding
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("b,l,n,f", [(8, 16, 100, 128), (5, 7, 33, 48), (16, 64, 1000, 128)])
def test_embedding_bag_matches_ref(mode, b, l, n, f):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, (b, l)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(b, l)).astype(np.float32))
    valid = jnp.asarray(rng.random((b, l)) > 0.2)
    got = embedding_bag(table, idx, w, valid, mode, use_kernel=True, interpret=True)
    ref = embedding_bag_ref(table, idx, w, valid, mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 20), l=st.integers(1, 40))
def test_embedding_bag_fuzz(seed, b, l):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, (b, l)).astype(np.int32))
    got = embedding_bag(table, idx, use_kernel=True, interpret=True)
    ref = embedding_bag_ref(table, idx, jnp.ones((b, l)), jnp.ones((b, l), bool))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=1e-5)


# ---------------------------------------------------------------- ell_agg
@pytest.mark.parametrize("r,d,f", [(8, 16, 128), (24, 8, 128), (10, 5, 70)])
def test_ell_agg_matches_ref(r, d, f):
    rng = np.random.default_rng(1)
    feats = jnp.asarray(rng.normal(size=(r, d, f)).astype(np.float32))
    valid = jnp.asarray(rng.random((r, d)) > 0.3)
    got = ell_multi_aggregate(feats, valid, use_kernel=True, interpret=True)
    ref = ell_multi_aggregate(feats, valid, use_kernel=False)
    for g, rf, nm in zip(got, ref, ("mean", "std", "max", "min")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(rf), rtol=2e-5, atol=1e-5, err_msg=nm)


def test_ell_agg_empty_rows_zero():
    feats = jnp.ones((8, 4, 128), jnp.float32)
    valid = jnp.zeros((8, 4), bool)
    for out in ell_multi_aggregate(feats, valid, use_kernel=True, interpret=True):
        np.testing.assert_allclose(np.asarray(out), 0.0)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,tq,tk,d", [(1, 2, 128, 128, 64), (2, 1, 256, 384, 128)])
def test_flash_attention_matches_ref(causal, b, h, tq, tk, d):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(b, h, tq, d)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.normal(size=(b, h, tk, d)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.normal(size=(b, h, tk, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, use_kernel=True, interpret=True)
    ref = flash_attention(q, k, v, causal=causal, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)).astype(np.float32)).astype(jnp.bfloat16) * 0.3
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)).astype(np.float32)).astype(jnp.bfloat16) * 0.3
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)).astype(np.float32)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, use_kernel=True, interpret=True)
    ref = attention_ref(
        q.reshape(2, 128, 64), k.reshape(2, 128, 64), v.reshape(2, 128, 64), causal=True
    ).reshape(1, 2, 128, 64)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=2e-2, atol=2e-2
    )

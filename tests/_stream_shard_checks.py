"""Sharded-streaming checks, executed in a subprocess with 8 host devices.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python tests/_stream_shard_checks.py <check-name>
Prints CHECK_OK on success (asserts otherwise).

Covers the PR acceptance criteria: bit-for-bit equivalence of the sharded
streaming advance to the single-host ``StreamingQuery`` across semirings and
window slides, shard-capacity growth under a live query, shard-locality of
appends/trims, SPMD window serving through ``QueryBatcher``, the per-shard
SPMD ELL path (``ell``: Pallas vrelax inside shard_map, scalar + Q-folded),
skew-aware shard assignments (``rebalance``: balanced/hash bit-for-bit plus
the ≤2x occupancy-spread bound), the one-collective-per-superstep
invariant checked against the lowered HLO (``collectives``, including the
ELL kernels), and a chaos schedule under live resharding (``chaos``: torn
cross-shard append + advance fault adjacent to 8→4→8 migrations, bit-for-bit
vs a fault-free reference of the same reshard schedule).
"""
from __future__ import annotations

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

V = 48
WINDOW = 3
N_SHARDS = 8


def _stream(seed=0, num_snapshots=10, batch_size=20):
    from repro.graph.generators import (
        generate_evolving_stream, generate_rmat, generate_uniform_weights,
    )

    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def _paired_logs(base, deltas, n_prime, *, capacity=512, shard_capacity=64):
    from repro.graph.shardlog import ShardedSnapshotLog
    from repro.graph.stream import SnapshotLog

    log = SnapshotLog(V, capacity=capacity)
    slog = ShardedSnapshotLog(V, N_SHARDS, capacity=shard_capacity)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: n_prime - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    return log, slog, deltas[n_prime - 1:]


def check_equivalence():
    """Sharded advance ≡ single-host StreamingQuery ≡ fresh evaluation,
    bit-for-bit, for 3 semirings over ≥4 window slides on 8 shards."""
    from repro.core.api import EvolvingQuery, StreamingQuery
    from repro.graph.shardlog import ShardedWindowView
    from repro.graph.stream import WindowView

    base, deltas = _stream()
    for query, source in (("sssp", 0), ("sswp", 5), ("bfs", 7)):
        log, slog, pending = _paired_logs(base, deltas, WINDOW)
        view = WindowView(log, size=WINDOW)
        sview = ShardedWindowView(slog, size=WINDOW)
        sq = StreamingQuery(view, query, source)
        ssq = StreamingQuery(sview, query, source)
        assert type(ssq).__name__ == "ShardedStreamingQuery", type(ssq)
        np.testing.assert_array_equal(sq.results, ssq.results)
        assert len(pending) >= 4
        for k, d in enumerate(pending):
            ref = sq.advance(d)
            got = ssq.advance(d)
            np.testing.assert_array_equal(
                got, ref, err_msg=f"{query} slide {k}: sharded != single-host"
            )
            fresh = EvolvingQuery(
                sview.materialize(), query, source
            ).evaluate("cqrs")
            np.testing.assert_array_equal(
                got, fresh, err_msg=f"{query} slide {k}: sharded != fresh"
            )
        assert ssq.stats["slides"] == len(pending)
    print("CHECK_OK")


def check_growth():
    """Per-shard universe growth (stacked-shape change) under a live sharded
    query must stay transparent — mirrors the single-host capacity test."""
    import repro.graph.stream as stream_mod
    from repro.core.api import StreamingQuery
    from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView
    from repro.graph.stream import SnapshotLog, WindowView
    from repro.utils.padding import round_up

    stream_mod.STREAM_ALIGN = 8
    base, deltas = _stream(seed=3)
    # probe: how full is the fullest shard at prime?  Size the real log so
    # that shard sits at exact capacity, then overflow it mid-stream.
    probe = ShardedSnapshotLog(V, N_SHARDS, capacity=512)
    probe.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        probe.append_snapshot(*d)
    cap0 = round_up(max(sh.num_edges for sh in probe.shards), 8)

    log = SnapshotLog(V, capacity=512)
    slog = ShardedSnapshotLog(V, N_SHARDS, capacity=cap0)
    log.append_snapshot(*base)
    slog.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
        slog.append_snapshot(*d)
    assert slog.capacity == cap0
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0)
    ssq = StreamingQuery(sview, "sssp", 0)
    np.testing.assert_array_equal(sq.results, ssq.results)
    for d in deltas[WINDOW - 1:]:
        np.testing.assert_array_equal(sq.advance(d), ssq.advance(d))
    # deterministic overflow: register fresh edges sinking on the fullest
    # shard until its capacity class must double, same delta to both logs
    s_max = int(np.argmax([sh.num_edges for sh in slog.shards]))
    sh = slog.shards[s_max]
    have = set(zip(sh.src[: sh.num_edges].tolist(),
                   sh.dst[: sh.num_edges].tolist()))
    need = sh.capacity - sh.num_edges + 1
    fresh = [
        (s, d)
        for d in range(s_max * slog.v_local, (s_max + 1) * slog.v_local)
        for s in range(V)
        if s != d and (s, d) not in have
    ][:need]
    assert len(fresh) == need, "graph too dense to overflow the shard"
    delta = ([s for s, _ in fresh], [d for _, d in fresh],
             [1.0 + 0.5 * i for i in range(need)], [], [])
    np.testing.assert_array_equal(sq.advance(delta), ssq.advance(delta))
    assert slog.capacity > cap0, "fullest shard did not grow"
    # and the next ordinary slide still matches on the regrown shapes
    extra = ([0], [s_max * slog.v_local], [7.25], [], [])
    np.testing.assert_array_equal(sq.advance(extra), ssq.advance(extra))
    print("CHECK_OK")


def check_serving():
    """SPMD window serving: QueryBatcher.watch/advance_window on a sharded
    view matches single-host watchers bit-for-bit."""
    from repro.graph.shardlog import ShardedWindowView
    from repro.graph.stream import WindowView
    from repro.serving.scheduler import QueryBatcher

    base, deltas = _stream(seed=4)
    log, slog, pending = _paired_logs(base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    qb = QueryBatcher()
    for v in (view, sview):
        qb.watch(v, "sssp", 0)
        qb.watch(v, "bfs", 7)
    for d in pending[:4]:
        ref = qb.advance_window(view, d)
        got = qb.advance_window(sview, d)
        assert set(got) == set(ref) == {("sssp", 0), ("bfs", 7)}
        for key in ref:
            np.testing.assert_array_equal(got[key], ref[key], err_msg=str(key))
    # consumed history is pruned and unreachable log prefixes retired per shard
    assert len(sview.history) == 0
    assert all(sh.retired_upto > 0 for sh in slog.shards)
    print("CHECK_OK")


def check_shard_local():
    """Appends and trims are shard-local: a delta only touches the shards
    owning its destinations, and every stored edge sinks in its shard."""
    from repro.graph.shardlog import ShardedSnapshotLog

    slog = ShardedSnapshotLog(V, N_SHARDS, capacity=64)
    v_local = slog.v_local
    base, deltas = _stream(seed=5)
    slog.append_snapshot(*base)
    for d in deltas:
        slog.append_snapshot(*d)
    for s, sh in enumerate(slog.shards):
        n = sh.num_edges
        assert n == 0 or (
            (sh.dst[:n] // v_local) == s
        ).all(), f"shard {s} stores a foreign-dst edge"
    # a delta aimed at one shard's dst range leaves all others untouched
    before = [(sh.num_edges, sh.weight_version) for sh in slog.shards]
    t = slog.append_snapshot([1, 2], [2 * v_local, 2 * v_local + 1],
                             [0.5, 0.25])
    for s, sh in enumerate(slog.shards):
        if s == 2:
            assert sh.num_edges >= before[s][0]
            added, removed = sh.snapshot_delta(t)
            assert len(added) == 2 and len(removed) == 0
        else:
            assert (sh.num_edges, sh.weight_version) == before[s], s
            added, removed = sh.snapshot_delta(t)
            assert len(added) == 0 and len(removed) == 0
    print("CHECK_OK")


def check_qbatch():
    """Batched SPMD serving (the Q-fold): Q=8 watchers on one sharded view
    grouped into ONE ShardedStreamingQueryBatch, each advance one Q-folded
    shard_map launch, bit-for-bit equal to single-host sequential watchers
    — for 2 semirings on cqrs plus an ELL group."""
    import numpy as np

    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.distributed.stream_shard import ShardedStreamingQueryBatch
    from repro.graph.shardlog import ShardedWindowView
    from repro.graph.stream import WindowView
    from repro.serving.scheduler import QueryBatcher

    base, deltas = _stream(seed=6)
    sources = [0, 5, 7, 11, 13, 21, 33, 40]
    for query in ("sssp", "sswp"):
        log, slog, pending = _paired_logs(base, deltas, WINDOW)
        view = WindowView(log, size=WINDOW)
        sview = ShardedWindowView(slog, size=WINDOW)
        qb = QueryBatcher()
        watchers = [qb.watch(sview, query, s) for s in sources]
        assert len({id(w.batch) for w in watchers}) == 1, \
            "watchers did not group into one batch entry"
        assert isinstance(watchers[0].batch, ShardedStreamingQueryBatch)
        assert watchers[0].batch.num_queries == len(sources)
        seqs = [StreamingQuery(view, query, s) for s in sources]
        for w, sq in zip(watchers, seqs):
            np.testing.assert_array_equal(w.results, sq.results)
        for d in pending[:4]:
            log.append_snapshot(*d)
            out = qb.advance_window(sview, d)
            assert set(out) == {(query, s) for s in sources}
            for s, sq in zip(sources, seqs):
                np.testing.assert_array_equal(
                    out[(query, s)], sq.advance(), err_msg=f"{query}/{s}"
                )
    # ELL group on the sharded path (Q folded into the kernel snapshot axis)
    log, slog, pending = _paired_logs(base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sqb = StreamingQueryBatch(sview, "bfs", sources[:4], method="cqrs_ell")
    seqs = [StreamingQuery(view, "bfs", s) for s in sources[:4]]
    for i, sq in enumerate(seqs):
        np.testing.assert_array_equal(sqb.results[i], sq.results)
    for d in pending[:2]:
        log.append_snapshot(*d)
        got = sqb.advance(d)
        for i, sq in enumerate(seqs):
            np.testing.assert_array_equal(got[i], sq.advance())
    print("CHECK_OK")


def check_collectives():
    """One-collective-per-superstep invariant, against the compiled HLO.

    The while-body of every sharded maintenance kernel must carry exactly one
    all-gather (the source-value/per-vertex-state gather) plus the scalar
    convergence all-reduce — and no other collective (no all-to-all, no
    collective-permute: the scatter side is shard-local by construction).
    """
    import re

    import jax.numpy as jnp
    from repro.core.semiring import SEMIRINGS
    from repro.distributed.stream_shard import _kernels, host_mesh

    mesh = host_mesh(N_SHARDS)
    e_cap = 64
    kernels = _kernels(mesh, SEMIRINGS["sssp"], V, e_cap, "model")
    n = N_SHARDS * e_cap
    vals = jnp.zeros(V, jnp.float32)
    edges = (jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32),
             jnp.zeros(n, jnp.float32), jnp.zeros(n, bool))
    src, dstl, w, active = edges
    source = jnp.int32(0)
    parent = jnp.zeros(V, jnp.int32)

    def ops(fn, *args):
        """Collective op *definitions* in the compiled HLO, by kind."""
        hlo = fn.lower(*args).compile().as_text()
        defs = re.findall(r"= \S+ ([\w-]*(?:all-gather|all-reduce|all-to-all|"
                          r"collective-permute)[\w-]*)\(", hlo)
        counts: dict[str, int] = {}
        for d in defs:
            for kind in ("all-gather", "all-reduce", "all-to-all",
                         "collective-permute"):
                if kind in d:
                    counts[kind] = counts.get(kind, 0) + 1
        return counts

    # The hot per-slide kernel: its single while-body must carry exactly one
    # all-gather (the source-value gather) and one all-reduce (the scalar
    # convergence psum) — nothing else crosses shards.
    c = base_fix = ops(kernels["fixpoint"], vals, src, dstl, w, active)
    assert c.get("all-gather", 0) == 1, c
    assert c.get("all-reduce", 0) == 1, c
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c

    # Trim-side kernels: per-vertex-state gathers only, no edge traffic.
    c = ops(kernels["invalidate"], vals, parent, active, src, source)
    assert c.get("all-gather", 0) == 1, c  # invalid-flag gather in the loop
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c
    c = ops(kernels["parents"], vals, src, dstl, w, active, source)
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c
    assert c.get("all-gather", 0) <= 3, c  # values + level loop + final level

    # The Q-batched serving kernels must keep the SAME schedule: the (Q, V)
    # state is split on the vertex axis, so each superstep still carries
    # exactly one all-gather (one op, Q rows tall) + the convergence psum
    # (now a (Q,) vector carrying per-lane freeze accounting — still ONE op).
    from repro.distributed.stream_shard import _kernels_q

    q = 8
    kq = _kernels_q(mesh, SEMIRINGS["sssp"], V, e_cap, "model", q)
    vals_q = jnp.zeros((q, V), jnp.float32)
    parent_q = jnp.zeros((q, V), jnp.int32)
    sources_q = jnp.zeros(q, jnp.int32)
    c = ops(kq["fixpoint"], vals_q, src, dstl, w, active)
    assert c.get("all-gather", 0) == 1, c
    assert c.get("all-reduce", 0) == 1, c
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c
    c = ops(kq["invalidate"], vals_q, parent_q, active, src, sources_q)
    assert c.get("all-gather", 0) == 1, c
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c
    c = ops(kq["parents"], vals_q, src, dstl, w, active, sources_q)
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c
    assert c.get("all-gather", 0) <= 3, c

    # The per-shard ELL kernels (Pallas vrelax inside shard_map) must lower
    # to the SAME schedule as the flat fixpoint: one all-gather of the
    # per-vertex state + one convergence all-reduce per superstep, no other
    # collective — the packed slot planes never cross shards.
    from repro.distributed.stream_shard import _ell_kernels

    ke = _ell_kernels(mesh, SEMIRINGS["sssp"], V, "model", True)
    r_rows, d_slots = 8, 128
    n_rows = N_SHARDS * r_rows
    esrc = jnp.zeros((n_rows, d_slots), jnp.int32)
    ew = jnp.zeros((n_rows, d_slots), jnp.float32)
    ewords = jnp.zeros((n_rows, d_slots, 1), jnp.uint32)
    erow2v = jnp.zeros(n_rows, jnp.int32)
    c = ops(ke["fixpoint"], vals, esrc, ew, ewords, erow2v)
    assert c.get("all-gather", 0) == 1, c
    assert c.get("all-reduce", 0) == 1, c
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c
    c = base_ellq = ops(ke["fixpoint_q"], vals_q, esrc, ew, ewords, erow2v)
    assert c.get("all-gather", 0) == 1, c
    assert c.get("all-reduce", 0) == 1, c
    assert c.get("all-to-all", 0) == 0 and c.get("collective-permute", 0) == 0, c

    # Observability must be HLO-invariant: with a live tracer AND an enabled
    # metrics registry, kernels built and lowered from scratch must compile
    # to the IDENTICAL collective schedule — spans/counters are host-side
    # only, so instrumentation may not add (or move) a single collective.
    from repro.obs.metrics import MetricsRegistry, use_registry
    from repro.obs.trace import Tracer, tracing

    with use_registry(MetricsRegistry()), tracing(Tracer()):
        k2 = _kernels(mesh, SEMIRINGS["sssp"], V, e_cap, "model")
        ke2 = _ell_kernels(mesh, SEMIRINGS["sssp"], V, "model", True)
        traced = {
            "fixpoint": ops(k2["fixpoint"], vals, src, dstl, w, active),
            "ell_fixpoint_q": ops(
                ke2["fixpoint_q"], vals_q, esrc, ew, ewords, erow2v
            ),
        }
    assert traced == {"fixpoint": base_fix, "ell_fixpoint_q": base_ellq}, (
        f"instrumentation changed the collective schedule: "
        f"{traced} vs base fixpoint={base_fix}, ell_q={base_ellq}"
    )
    print("CHECK_OK")


def check_ell():
    """Per-shard SPMD ELL (Pallas vrelax under shard_map) on 8 shards:
    scalar and Q-batched cqrs_ell advances bit-for-bit equal to the
    single-host engine, with sticky stacked ELL shapes across slides."""
    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.graph.shardlog import ShardedWindowView
    from repro.graph.stream import WindowView

    base, deltas = _stream(seed=7)
    log, slog, pending = _paired_logs(base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sq = StreamingQuery(view, "sssp", 0, method="cqrs_ell")
    ssq = StreamingQuery(sview, "sssp", 0, method="cqrs_ell")
    np.testing.assert_array_equal(sq.results, ssq.results)
    shapes = []
    for k, d in enumerate(pending):
        np.testing.assert_array_equal(
            sq.advance(d), ssq.advance(d),
            err_msg=f"sharded ELL != single-host at slide {k}",
        )
        _, dev = ssq._ell_cache.pack()
        shapes.append(tuple(dev["src"].shape))
    assert len(set(shapes)) == 1, f"stacked ELL shapes churned: {shapes}"
    # Q-batched: Q folded into the per-shard kernel's snapshot axis
    log, slog, pending = _paired_logs(base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    sview = ShardedWindowView(slog, size=WINDOW)
    sources = [0, 5, 7, 11]
    sqb = StreamingQueryBatch(sview, "sswp", sources, method="cqrs_ell")
    seqs = [StreamingQuery(view, "sswp", s, method="cqrs_ell")
            for s in sources]
    for i, s in enumerate(seqs):
        np.testing.assert_array_equal(sqb.results[i], s.results)
    for d in pending[:3]:
        log.append_snapshot(*d)
        got = sqb.advance(d)
        for i, s in enumerate(seqs):
            np.testing.assert_array_equal(got[i], s.advance())
    print("CHECK_OK")


def check_rebalance():
    """Skew-aware shard assignments on 8 shards: balanced-range and
    hash-of-dst sharded advances are bit-for-bit equal to the single-host
    engine for both engines, and the balanced assignment actually evens
    out per-shard occupancy on the skewed RMAT stream."""
    from repro.core.api import StreamingQuery
    from repro.graph.shardlog import (
        ShardedSnapshotLog, ShardedWindowView, degree_histogram,
    )
    from repro.graph.stream import SnapshotLog, WindowView

    base, deltas = _stream(seed=8)
    hist = degree_histogram(base, deltas, V)
    spreads = {}
    for mode in ("range", "balanced", "hash"):
        slog = ShardedSnapshotLog.from_stream(
            base, deltas, V, N_SHARDS, capacity=64,
            assignment=mode, degree_hist=hist,
        )
        spreads[mode] = slog.occupancy_spread()
    assert spreads["balanced"] < spreads["range"], spreads
    assert spreads["balanced"] <= 2.0, spreads

    for mode in ("balanced", "hash"):
        for query, source, method in (
            ("sssp", 0, "cqrs"), ("sswp", 5, "cqrs_ell"),
            ("bfs", 7, "cqrs"),
        ):
            log = SnapshotLog(V, capacity=512)
            slog = ShardedSnapshotLog(V, N_SHARDS, capacity=64,
                                      assignment=mode, degree_hist=hist)
            log.append_snapshot(*base)
            slog.append_snapshot(*base)
            for d in deltas[: WINDOW - 1]:
                log.append_snapshot(*d)
                slog.append_snapshot(*d)
            view = WindowView(log, size=WINDOW)
            sview = ShardedWindowView(slog, size=WINDOW)
            sq = StreamingQuery(view, query, source, method=method)
            ssq = StreamingQuery(sview, query, source, method=method)
            np.testing.assert_array_equal(sq.results, ssq.results)
            for k, d in enumerate(deltas[WINDOW - 1: WINDOW + 2]):
                np.testing.assert_array_equal(
                    sq.advance(d), ssq.advance(d),
                    err_msg=f"{mode}/{query}/{method} slide {k}",
                )
    print("CHECK_OK")


def check_warmstart():
    """Elastic warm restore across REAL device counts: a replica serving on
    2 shards is checkpointed mid-stream and restored onto 4 shards and onto
    a single host; every slide served after the restore is bit-for-bit
    equal to the uninterrupted 2-shard stream (scalar cqrs + batched
    cqrs_ell).  The checkpoint stores global-space values, and min/max
    segment reductions are order-exact, so the shard layout is free."""
    from repro.checkpoint import resume_streaming, streaming_state
    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView

    base, deltas = _stream(seed=11)

    def shard_replica(n_shards, *, batch=False, method="cqrs"):
        slog = ShardedSnapshotLog(V, n_shards, capacity=256)
        slog.append_snapshot(*base)
        for d in deltas[: WINDOW - 1]:
            slog.append_snapshot(*d)
        sview = ShardedWindowView(slog, size=WINDOW)
        if batch:
            return StreamingQueryBatch(sview, "sssp", [0, 7, 13],
                                       method=method)
        return StreamingQuery(sview, "sswp", 5, method=method)

    for batch, method in ((False, "cqrs"), (True, "cqrs_ell")):
        ref_sq = shard_replica(2, batch=batch, method=method)
        pending = deltas[WINDOW - 1:]
        ref = [np.asarray(ref_sq.results).copy()]
        for d in pending:
            ref_sq.advance(d)
            ref.append(np.asarray(ref_sq.results).copy())

        sq = shard_replica(2, batch=batch, method=method)
        sq.results
        sq.advance(pending[0])
        sq.advance(pending[1])
        tree, extra = streaming_state(sq)
        for n in (4, 0):  # grow the mesh / shrink to a single host
            restored = resume_streaming(tree, extra, n_shards=n)
            got = np.asarray(restored.results)
            np.testing.assert_array_equal(
                got, ref[2], err_msg=f"2->{n} shards restore point"
            )
            for j, d in enumerate(pending[2:], start=2):
                restored.advance(d)
                np.testing.assert_array_equal(
                    np.asarray(restored.results), ref[j + 1],
                    err_msg=f"2->{n} shards slide {j} "
                            f"(batch={batch}, {method})",
                )
    print("CHECK_OK")


def check_reshard():
    """Live layout migration on a REAL 8-device mesh: a serving query is
    resharded 8→4 shards (mesh shrink) and back 4→8 mid-stream; every slide
    after each migration is bit-for-bit equal to a never-resharded 8-shard
    run, with ZERO fixpoint re-solves (supersteps unchanged, exactly the two
    parent-forest recomputes per migration).  Afterwards the kernels built
    for the migrated mesh must still lower to the one-all-gather + one
    all-reduce per-superstep schedule — migration may not perturb the
    collective pin."""
    import re

    import jax.numpy as jnp
    from repro.core.api import StreamingQuery, StreamingQueryBatch
    from repro.core.semiring import SEMIRINGS
    from repro.distributed.stream_shard import _kernels
    from repro.graph.shardlog import ShardedSnapshotLog, ShardedWindowView

    base, deltas = _stream(seed=13)

    def replica(*, batch=False, method="cqrs"):
        slog = ShardedSnapshotLog(V, N_SHARDS, capacity=64)
        slog.append_snapshot(*base)
        for d in deltas[: WINDOW - 1]:
            slog.append_snapshot(*d)
        sview = ShardedWindowView(slog, size=WINDOW)
        if batch:
            return StreamingQueryBatch(sview, "sssp", [0, 7, 13],
                                       method=method)
        return StreamingQuery(sview, "sswp", 5, method=method)

    for batch, method in ((False, "cqrs"), (True, "cqrs_ell")):
        pending = deltas[WINDOW - 1:]
        ref_sq = replica(batch=batch, method=method)
        ref = [np.asarray(ref_sq.results).copy()]
        for d in pending:
            ref_sq.advance(d)
            ref.append(np.asarray(ref_sq.results).copy())

        sq = replica(batch=batch, method=method)
        sq.results
        sq.advance(pending[0])
        sq.advance(pending[1])
        log = sq.view.log
        for k, n_to in enumerate((4, 8)):  # shrink the mesh, then regrow
            pre_ss = sq._bounds.supersteps
            pre_la = sq._bounds.launches
            target = log.assignment.resize(n_to, log.live_degree_histogram())
            report = sq.reshard(target)
            assert report["n_shards"] == n_to == log.n_shards
            assert report["epoch"] == log.assignment.epoch
            assert sq.mesh.devices.size == n_to
            # zero re-solves: the warm fixpoints moved, they were not redone
            assert sq._bounds.supersteps == pre_ss, \
                f"migration re-solved a fixpoint ({batch}, {method})"
            assert sq._bounds.launches == pre_la + 2, \
                "migration should cost exactly the two parent recomputes"
            got = np.asarray(sq.results)
            np.testing.assert_array_equal(
                got, ref[2 + k], err_msg=f"8->{n_to} restore point"
            )
            sq.advance(pending[2 + k])
        for j, d in enumerate(pending[4:], start=4):
            sq.advance(d)
            np.testing.assert_array_equal(
                np.asarray(sq.results), ref[j + 1],
                err_msg=f"post-migration slide {j} (batch={batch}, {method})",
            )

    # the collective pin survives migration: kernels for the final (regrown)
    # mesh still carry exactly one all-gather + one all-reduce per superstep
    mesh = sq.mesh
    e_cap = int(log.capacity)
    kernels = _kernels(mesh, SEMIRINGS["sssp"], V, e_cap, "model")
    n = log.n_shards * e_cap
    vals = jnp.zeros(V, jnp.float32)
    args = (vals, jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.int32),
            jnp.zeros(n, jnp.float32), jnp.zeros(n, bool))
    hlo = kernels["fixpoint"].lower(*args).compile().as_text()
    defs = re.findall(r"= \S+ ([\w-]*(?:all-gather|all-reduce|all-to-all|"
                      r"collective-permute)[\w-]*)\(", hlo)
    counts: dict[str, int] = {}
    for d in defs:
        for kind in ("all-gather", "all-reduce", "all-to-all",
                     "collective-permute"):
            if kind in d:
                counts[kind] = counts.get(kind, 0) + 1
    assert counts.get("all-gather", 0) == 1, counts
    assert counts.get("all-reduce", 0) == 1, counts
    assert counts.get("all-to-all", 0) == 0, counts
    assert counts.get("collective-permute", 0) == 0, counts
    print("CHECK_OK")


def check_chaos():
    """Chaos under live resharding on the REAL 8-device mesh: a torn
    cross-shard append self-heals, the serving group is migrated 8→4 shards
    mid-stream, an advance fault under the shrunk layout rolls back
    transactionally (degraded slide, then retry), the group regrows 4→8 —
    and every post-drain slide is bit-for-bit equal to a fault-free run of
    the SAME reshard schedule."""
    from repro.ft.chaos import ChaosHarness
    from repro.ft.faultinject import FaultPlan, FaultSpec

    def on_slide(i, view, qb):
        n_to = {1: 4, 2: 8}.get(i)
        if n_to is None:
            return
        log = view.log
        target = log.assignment.resize(n_to, log.live_degree_histogram())
        for b in {id(x): x for x in qb._batches.values()
                  if x.view is view}.values():
            b.reshard(target)
        assert log.n_shards == n_to

    h = ChaosHarness(num_snapshots=9, n_shards=N_SHARDS, on_slide=on_slide)
    plan = FaultPlan(specs=(
        # torn cross-shard append on shard 3, first served slide
        FaultSpec(site="ingest_shard", slide=0, shard=3),
        # advance fault on the slide right after the 8→4 migration
        FaultSpec(site="advance_qrs_patch", slide=2),
    ))
    report = h.run(plan)
    assert report["faults_fired"] == 2, report["fired"]
    assert report["converged"], report["mismatches"]
    assert report["degraded_slides"] >= 1, report
    assert report["events"].get("ingest_fault", 0) == 1, report["events"]
    assert report["events"].get("rollback", 0) >= 1, report["events"]
    print("CHECK_OK")


if __name__ == "__main__":
    globals()[f"check_{sys.argv[1]}"]()

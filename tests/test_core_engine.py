"""Engine correctness: fixpoint vs pure-numpy Bellman-Ford oracle."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import compute_fixpoint, compute_parents
from repro.core.semiring import SEMIRINGS, viterbi_weights
from repro.graph.generators import generate_rmat, generate_uniform_weights
from repro.graph.structures import EdgeList

from conftest import reference_fixpoint


def _random_graph(v=48, e=160, seed=0):
    src, dst = generate_rmat(v, e, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return EdgeList.from_numpy(src, dst, w, v)


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
@pytest.mark.parametrize("seed", [0, 7])
def test_fixpoint_matches_oracle(name, seed):
    sr = SEMIRINGS[name]
    el = _random_graph(seed=seed)
    w = el.weight
    if name == "viterbi":
        w = viterbi_weights(w)
    vals, iters = compute_fixpoint(
        el.src, el.dst, w, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    ref = reference_fixpoint(el.src, el.dst, w, el.valid, sr, 0, el.num_vertices)
    np.testing.assert_allclose(np.asarray(vals), ref, rtol=1e-6)
    assert int(iters) <= el.num_vertices + 1


def test_source_value_pinned():
    sr = SEMIRINGS["sssp"]
    el = _random_graph(seed=3)
    vals, _ = compute_fixpoint(
        el.src, el.dst, el.weight, el.valid, sr, jnp.int32(5), el.num_vertices
    )
    assert float(vals[5]) == 0.0


def test_parents_are_achieving_edges():
    sr = SEMIRINGS["sssp"]
    el = _random_graph(seed=1)
    vals, _ = compute_fixpoint(
        el.src, el.dst, el.weight, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    parent = compute_parents(
        vals, el.src, el.dst, el.weight, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    vals_np, parent_np = np.asarray(vals), np.asarray(parent)
    src_np, dst_np, w_np = np.asarray(el.src), np.asarray(el.dst), np.asarray(el.weight)
    for v in range(el.num_vertices):
        p = parent_np[v]
        if p < 0:
            continue
        assert dst_np[p] == v
        assert np.isclose(vals_np[src_np[p]] + w_np[p], vals_np[v])
    # source + unreached vertices have no parent
    assert parent_np[0] == -1
    unreached = ~np.isfinite(vals_np)
    assert (parent_np[unreached] == -1).all()

"""Engine correctness: fixpoint vs pure-numpy Bellman-Ford oracle."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    PARENT_FRAGILE,
    compute_fixpoint,
    compute_parents,
    invalidate_from_deletions,
)
from repro.core.semiring import SEMIRINGS, viterbi_weights
from repro.graph.generators import generate_rmat, generate_uniform_weights
from repro.graph.structures import EdgeList

from conftest import reference_fixpoint


def _random_graph(v=48, e=160, seed=0):
    src, dst = generate_rmat(v, e, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return EdgeList.from_numpy(src, dst, w, v)


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
@pytest.mark.parametrize("seed", [0, 7])
def test_fixpoint_matches_oracle(name, seed):
    sr = SEMIRINGS[name]
    el = _random_graph(seed=seed)
    w = el.weight
    if name == "viterbi":
        w = viterbi_weights(w)
    vals, iters = compute_fixpoint(
        el.src, el.dst, w, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    ref = reference_fixpoint(el.src, el.dst, w, el.valid, sr, 0, el.num_vertices)
    np.testing.assert_allclose(np.asarray(vals), ref, rtol=1e-6)
    assert int(iters) <= el.num_vertices + 1


def test_source_value_pinned():
    sr = SEMIRINGS["sssp"]
    el = _random_graph(seed=3)
    vals, _ = compute_fixpoint(
        el.src, el.dst, el.weight, el.valid, sr, jnp.int32(5), el.num_vertices
    )
    assert float(vals[5]) == 0.0


def test_parents_are_achieving_edges():
    sr = SEMIRINGS["sssp"]
    el = _random_graph(seed=1)
    vals, _ = compute_fixpoint(
        el.src, el.dst, el.weight, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    parent = compute_parents(
        vals, el.src, el.dst, el.weight, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    vals_np, parent_np = np.asarray(vals), np.asarray(parent)
    src_np, dst_np, w_np = np.asarray(el.src), np.asarray(el.dst), np.asarray(el.weight)
    for v in range(el.num_vertices):
        p = parent_np[v]
        if p < 0:
            continue
        assert dst_np[p] == v
        assert np.isclose(vals_np[src_np[p]] + w_np[p], vals_np[v])
    # source + unreached vertices have no parent
    assert parent_np[0] == -1
    unreached = ~np.isfinite(vals_np)
    assert (parent_np[unreached] == -1).all()


@pytest.mark.parametrize("name", sorted(SEMIRINGS))
@pytest.mark.parametrize("seed", [1, 4])
def test_parent_forest_acyclic_and_complete(name, seed):
    """Every parent chain must walk back to a dependence-free vertex.

    Acyclicity is what makes the KickStarter trim sound: with a non-strict
    ``extend`` an arbitrary achieving-edge choice can record an equal-value
    cycle's members as each other's parents, which a chain walk exposes as
    an infinite loop.  At a true fixpoint no vertex should need the fragile
    fallback either.
    """
    sr = SEMIRINGS[name]
    el = _random_graph(seed=seed)
    w = el.weight if name != "viterbi" else viterbi_weights(el.weight)
    vals, _ = compute_fixpoint(
        el.src, el.dst, w, el.valid, sr, jnp.int32(0), el.num_vertices
    )
    parent = np.asarray(compute_parents(
        vals, el.src, el.dst, w, el.valid, sr, jnp.int32(0), el.num_vertices
    ))
    assert (parent != PARENT_FRAGILE).all()
    src_np = np.asarray(el.src)
    for v in range(el.num_vertices):
        u, hops = v, 0
        while parent[u] >= 0:
            u = src_np[parent[u]]
            hops += 1
            assert hops <= el.num_vertices, f"parent cycle through vertex {v}"


def test_trim_breaks_equal_value_cycle():
    """Regression: sswp cycle 1↔2 (w=9) fed by sole support 0→1 (w=5).

    Both cycle vertices converge to 5 and every cycle edge is achieving, so
    an arbitrary achieving-edge parent lets them justify each other; deleting
    the support must still invalidate both (the BFS-levelled forest roots
    their chains in edge 0→1).
    """
    sr = SEMIRINGS["sswp"]
    src = jnp.asarray([1, 2, 0], jnp.int32)
    dst = jnp.asarray([2, 1, 1], jnp.int32)
    w = jnp.asarray([9.0, 9.0, 5.0], jnp.float32)
    valid = jnp.ones(3, bool)
    vals, _ = compute_fixpoint(src, dst, w, valid, sr, jnp.int32(0), 5,
                               sorted_edges=False)
    assert np.asarray(vals)[1] == 5.0 and np.asarray(vals)[2] == 5.0
    parent = compute_parents(vals, src, dst, w, valid, sr, jnp.int32(0), 5,
                             sorted_edges=False)
    deleted = jnp.asarray([False, False, True])  # drop the support edge
    trimmed, invalid = invalidate_from_deletions(
        vals, parent, deleted, src, sr, jnp.int32(0), 5
    )
    assert bool(invalid[1]) and bool(invalid[2])
    assert np.asarray(trimmed)[1] == sr.identity
    assert np.asarray(trimmed)[2] == sr.identity

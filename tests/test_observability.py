"""Observability layer contracts: metrics, tracing, stability telemetry.

What this file pins:

* registry primitives — counter/gauge/histogram semantics, lazy gauge
  values (callables and device arrays resolved only at export), the
  disabled registry being inert, name→kind conflicts raising;
* export surfaces — snapshot / JSON-lines / Prometheus text exposition
  round-trips, the ``EventLog`` JSON-lines sink, and a live ``/metrics``
  scrape through ``serve_prometheus``;
* **metrics-on ≡ metrics-off**: serving with a live tracer AND an enabled
  registry is bit-for-bit equal to serving with everything disabled —
  observability may not perturb a single float;
* the pipelined slide's span tree covers EVERY phase (``PHASES``) and spans
  land from both the caller and the batcher's worker thread, with ``ready``
  timestamps stamped at the materialization sync points;
* **sync ≡ async accounting**: the synchronous and pipelined serving routes
  produce identical registry counters and gauges (kernel launches, presence
  touched/rebuilds, slides, QRS churn) — one accounting, two schedules;
* stability gauges match ground truth recomputed from ``materialize()``
  (UVV fraction vs a fresh ``compute_bounds``/``detect_uvv``, QRS edge
  fraction vs an independent union-mask count, bounds-match rate vs the
  served rows themselves);
* presence/packer counters mirror the test-pinned per-instance façades
  exactly (``EllPresenceCache.touched``/``rebuilds``);
* ``HeartbeatMonitor`` missed-beat events + last-beat-age gauge, and
  ``ServeSupervisor`` restart events (cause, restore slide, catch-up
  depth) with checkpoint save/restore timers;
* the BENCH json schema-v2 ``metrics`` block validation.
"""
from __future__ import annotations

import json
import urllib.request

import numpy as np
import pytest

from repro.core.api import StreamingQuery, StreamingQueryBatch
from repro.core.bounds import compute_bounds
from repro.core.semiring import SEMIRINGS
from repro.ft import HeartbeatMonitor, ServeSupervisor
from repro.graph.generators import (
    generate_evolving_stream,
    generate_rmat,
    generate_uniform_weights,
)
from repro.graph.stream import SnapshotLog, WindowView
from repro.obs.export import (
    EventLog,
    serve_prometheus,
    snapshot,
    to_jsonl,
    to_prometheus,
)
from repro.obs.metrics import (
    MetricsRegistry,
    disabled,
    get_registry,
    resolve_value,
    use_registry,
)
from repro.obs.stability import window_union_edges
from repro.obs.trace import PHASES, Tracer, get_tracer, span, tracing
from repro.serving.scheduler import QueryBatcher

V = 48
WINDOW = 3


def make_stream(seed: int, *, num_snapshots: int = WINDOW + 4, batch_size: int = 20):
    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    return generate_evolving_stream(
        src, dst, w, V, num_snapshots=num_snapshots, batch_size=batch_size,
        readd_prob=0.4, seed=seed + 2,
    )


def feed(log, base, deltas, upto: int):
    log.append_snapshot(*base)
    for d in deltas[: upto - 1]:
        log.append_snapshot(*d)
    return log


def primed_view(seed: int):
    base, deltas = make_stream(seed)
    log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    return WindowView(log, size=WINDOW), deltas[WINDOW - 1:]


# ===================================================================
# registry primitives
# ===================================================================
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter")
    c.inc()
    c.inc(2, lane="3")
    assert c.value() == 1
    assert c.value(lane="3") == 2
    assert reg.counter("c_total") is c  # name → same instrument

    g = reg.gauge("g", "a gauge")
    g.set(1.5)
    g.set(lambda: 7.0, kind="lazy")  # resolved at read, not at set
    assert g.value() == 1.5
    assert g.value(kind="lazy") == 7.0

    import jax.numpy as jnp

    g.set(jnp.float32(2.25), kind="dev")  # device scalar stays lazy
    assert g.value(kind="dev") == 2.25

    h = reg.histogram("h_seconds", "a histogram", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 99.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 3 and snap["sum"] == pytest.approx(101.0)
    assert snap["buckets"] == [1, 2, 3]  # cumulative le counts incl. +Inf

    with pytest.raises(TypeError):
        reg.gauge("c_total")  # kind conflict on an existing name


def test_disabled_registry_is_inert():
    reg = MetricsRegistry(enabled=False)
    reg.counter("c").inc()
    reg.gauge("g").set(3)
    reg.histogram("h").observe(1.0)
    assert reg.counter("c").value() == 0
    assert reg.gauge("g").value() is None
    assert reg.histogram("h").snapshot()["count"] == 0
    with reg.timer("t"):
        pass
    assert reg.histogram("t").snapshot()["count"] == 0


def test_disabled_context_and_null_span():
    with use_registry(MetricsRegistry()):
        with disabled():
            assert not get_registry().enabled
            # no tracer + disabled registry → the shared null span
            s1, s2 = span("fixpoint"), span("fetch")
            assert s1 is s2
            with s1:
                pass
        assert get_registry().enabled


def test_timer_observes_wall_seconds():
    reg = MetricsRegistry()
    with reg.timer("op_seconds", "timed", stage="x"):
        pass
    snap = reg.histogram("op_seconds").snapshot(stage="x")
    assert snap["count"] == 1 and 0 <= snap["sum"] < 5.0


def test_resolve_value():
    assert resolve_value(2) == 2.0
    assert resolve_value(lambda: 3.5) == 3.5
    assert resolve_value(np.float32(0.25)) == 0.25


# ===================================================================
# export surfaces
# ===================================================================
def test_snapshot_and_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("hits_total", "hits").inc(3, route="a")
    reg.gauge("depth", "queue depth").set(lambda: 4.0)
    reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)).observe(0.05)
    snap = snapshot(reg)
    assert snap["counters"] == {'hits_total{route="a"}': 3.0}
    assert snap["gauges"] == {"depth": 4.0}
    hist = snap["histograms"]["lat_seconds"]
    assert hist["buckets"] == [1, 1, 1] and hist["count"] == 1

    text = to_prometheus(reg)
    assert "# HELP hits_total hits" in text
    assert "# TYPE hits_total counter" in text
    assert 'hits_total{route="a"} 3.0' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_sum 0.05" in text
    assert "lat_seconds_count 1" in text

    rec = json.loads(to_jsonl(reg, slide=7))
    assert rec["slide"] == 7 and rec["counters"] == snap["counters"]
    assert "ts" in rec


def test_event_log_jsonl_file(tmp_path):
    path = tmp_path / "events.jsonl"
    log = EventLog(str(path))
    log.emit("restart", worker=0, cause="boom")
    log.emit("missed_beat", worker=1)
    assert [e["event"] for e in log.events] == ["restart", "missed_beat"]
    assert log.of_kind("restart")[0]["cause"] == "boom"
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == 2 and lines[0]["event"] == "restart"
    assert all("ts" in l for l in lines)


def test_serve_prometheus_scrape():
    reg = MetricsRegistry()
    reg.counter("scraped_total", "scrape me").inc(5)
    server = serve_prometheus(0, reg)  # port 0: any free port
    try:
        url = f"http://127.0.0.1:{server.server_port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert "scraped_total 5.0" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{server.server_port}/nope", timeout=10
            )
    finally:
        server.shutdown()


# ===================================================================
# metrics-on ≡ metrics-off (the zero-perturbation contract)
# ===================================================================
@pytest.mark.parametrize("method", ["cqrs", "cqrs_ell"])
def test_metrics_on_bit_for_bit_equals_metrics_off(method):
    view_on, pending = primed_view(seed=9)
    view_off, _ = primed_view(seed=9)
    with use_registry(MetricsRegistry()), tracing(Tracer()):
        sq_on = StreamingQuery(view_on, "sssp", 0, method=method)
        on = [np.asarray(sq_on.results).copy()]
        for d in pending:
            sq_on.advance(d)
            on.append(np.asarray(sq_on.results).copy())
    with use_registry(MetricsRegistry(enabled=False)):
        sq_off = StreamingQuery(view_off, "sssp", 0, method=method)
        off = [np.asarray(sq_off.results).copy()]
        for d in pending:
            sq_off.advance(d)
            off.append(np.asarray(sq_off.results).copy())
    for k, (a, b) in enumerate(zip(on, off)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"{method}: metrics-on != metrics-off at slide {k}"
        )


# ===================================================================
# span tree of a pipelined slide
# ===================================================================
def test_pipelined_span_tree_covers_every_phase():
    view, pending = primed_view(seed=12)
    tracer = Tracer()
    with use_registry(MetricsRegistry()), tracing(tracer):
        qb = QueryBatcher(method="cqrs_ell", pipelined=True)
        for x in (0, 7):
            qb.watch(view, "sssp", x, method="cqrs_ell")
        futs = [qb.advance_window_async(view, d) for d in pending[:2]]
        for f in futs:
            f.result()
        qb.close()
    names = tracer.names()
    assert set(PHASES) <= names, f"missing phases: {set(PHASES) - names}"
    # the ingest phases ran on the batcher's worker thread, the fetch on the
    # caller's — the tracer must have heard from both
    assert len(tracer.threads()) >= 2, tracer.threads()
    ended = [r for r in tracer.spans if r.name in PHASES]
    assert ended and all(r.wall is not None and r.wall >= 0 for r in ended)
    # ready stamps: at least one fixpoint span was marked at a materialize
    # sync point, and readiness never precedes the span's own start
    fixed = [r for r in tracer.spans if r.name == "fixpoint"
             and r.ready is not None]
    assert fixed, "no fixpoint span was marked ready at materialization"
    assert all(r.ready >= r.start for r in fixed)


def test_span_seconds_histogram_without_tracer():
    """The registry alone (no tracing session) still collects per-phase
    wall timings through the same span() call sites."""
    reg = MetricsRegistry()
    with use_registry(reg):
        assert get_tracer() is None
        with span("qrs_patch"):
            pass
    snap = reg.histogram("span_seconds").snapshot(phase="qrs_patch")
    assert snap["count"] == 1


# ===================================================================
# sync ≡ async accounting (one ledger, two schedules)
# ===================================================================
def test_sync_and_pipelined_accounting_identical():
    base, deltas = make_stream(seed=5)
    runs = {}
    for mode, pipelined in (("sync", False), ("pipe", True)):
        log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
        view = WindowView(log, size=WINDOW)
        reg = MetricsRegistry()
        with use_registry(reg):
            qb = QueryBatcher(method="cqrs_ell", pipelined=pipelined)
            for x in (0, 7, 13):
                qb.watch(view, "sssp", x, method="cqrs_ell")
            outs = [qb.advance_window(view, d) for d in deltas[WINDOW - 1:]]
            batches = list(qb._batches.values())
            snap = snapshot(reg)  # resolve before qb/query teardown
            qb.close()
        touched = []
        rebuilds = 0
        for b in batches:
            for cache in getattr(b, "_presence", {}).values():
                touched += cache.touched
                rebuilds += cache.rebuilds
        runs[mode] = (outs, snap, touched, rebuilds)
    outs_s, snap_s, touched_s, rebuilds_s = runs["sync"]
    outs_p, snap_p, touched_p, rebuilds_p = runs["pipe"]
    for k, (a, b) in enumerate(zip(outs_s, outs_p)):
        assert set(a) == set(b)
        for key in a:
            np.testing.assert_array_equal(
                a[key], b[key], err_msg=f"slide {k} lane {key}"
            )
    # the per-instance façades agree across schedules ...
    assert touched_s == touched_p
    assert rebuilds_s == rebuilds_p
    # ... and so does EVERY registry counter and gauge: kernel launches,
    # presence touched/rebuilds, slides served, QRS churn, supersteps
    assert snap_s["counters"] == snap_p["counters"]
    assert snap_s["gauges"] == snap_p["gauges"]
    # the mirrored presence counters equal the pinned façade exactly
    assert snap_s["counters"].get("presence_touched_slots_total", 0) == \
        sum(touched_s)
    assert snap_s["counters"].get("presence_rebuilds_total", 0) == rebuilds_s


# ===================================================================
# stability gauges vs ground truth
# ===================================================================
def test_stability_gauges_match_ground_truth():
    view, pending = primed_view(seed=3)
    reg = MetricsRegistry()
    with use_registry(reg):
        sq = StreamingQuery(view, "sssp", 0, method="cqrs")
        sq.results
        for d in pending:
            sq.advance(d)
        labels = {"query": "sssp", "source": "0"}

        # UVV fraction == a fresh intersection/union analysis of the
        # materialized window (Theorem 2 ground truth)
        ref = compute_bounds(view.materialize(), SEMIRINGS["sssp"], 0)
        want_uvv = float(np.asarray(ref.uvv).mean())
        got_uvv = reg.gauge("stream_uvv_fraction").value(**labels)
        assert got_uvv == pytest.approx(want_uvv)

        # QRS edge fraction == resident QRS edges over an independently
        # counted union-mask denominator
        union_edges = int(
            np.asarray(view.union_mask()[: view.log.num_edges]).sum()
        )
        assert window_union_edges(view) == union_edges
        want_frac = sq._qrs.num_edges / union_edges
        got_frac = reg.gauge("stream_qrs_edge_fraction").value(**labels)
        assert got_frac == pytest.approx(want_frac)
        assert 0.0 < got_frac <= 1.0

        # QRS vertex fraction == 1 - mean of the folded keep mask
        want_vfrac = float(1.0 - np.asarray(sq._qrs.uvv).mean())
        got_vfrac = reg.gauge("stream_qrs_vertex_fraction").value(**labels)
        assert got_vfrac == pytest.approx(want_vfrac)

        # bounds-match rate == newest served row vs the live G∩ bound
        newest = np.asarray(sq.results)[-1]
        want_match = float(
            (newest == np.asarray(sq._bounds.val_cap)).mean()
        )
        got_match = reg.gauge("stream_bounds_match_rate").value(**labels)
        assert got_match == pytest.approx(want_match)

        # slide counter == the number of advances we made
        assert reg.counter("stream_slides_total").value(**labels) == \
            len(pending)
        # maintenance ledgers mirrored exactly
        assert reg.counter("stream_trims_total").value(**labels) == \
            sq._bounds.trims
        assert reg.counter("stream_rerelaxes_total").value(**labels) == \
            sq._bounds.rerelaxes


def test_stability_gauges_live_after_query_freed():
    """Weakref lazy gauges degrade to 0.0 once the query is gone — an
    evicted watcher must not be kept alive by the registry."""
    view, pending = primed_view(seed=4)
    reg = MetricsRegistry()
    with use_registry(reg):
        sq = StreamingQuery(view, "sssp", 0, method="cqrs")
        sq.results
        sq.advance(pending[0])
    labels = {"query": "sssp", "source": "0"}
    assert reg.gauge("stream_qrs_edge_fraction").value(**labels) > 0
    del sq
    import gc

    gc.collect()
    assert reg.gauge("stream_qrs_edge_fraction").value(**labels) == 0.0


# ===================================================================
# heartbeat + supervisor events
# ===================================================================
def test_heartbeat_missed_beat_event_and_age_gauge():
    clock = {"t": 0.0}
    events = EventLog()
    reg = MetricsRegistry()
    with use_registry(reg):
        hb = HeartbeatMonitor(
            num_workers=2, timeout=10.0, clock=lambda: clock["t"],
            events=events,
        )
        hb.beat(0)
        hb.beat(1)
        clock["t"] = 5.0
        hb.beat(0)  # worker 1 goes quiet
        assert hb.dead_workers() == set()
        # the age gauge is lazy: it reads the clock at scrape time
        assert reg.gauge("heartbeat_last_beat_age_seconds").value(
            worker="1"
        ) == pytest.approx(5.0)
        clock["t"] = 12.0
        hb.beat(0)  # worker 0 stays chatty
        clock["t"] = 16.0
        assert hb.dead_workers() == {1}
        assert hb.dead_workers() == {1}  # second poll: no duplicate event
    (ev,) = events.of_kind("missed_beat")
    assert ev["worker"] == 1
    assert ev["age"] == pytest.approx(16.0)
    assert ev["timeout"] == 10.0
    assert reg.counter("heartbeat_missed_beats_total").value(worker="1") == 1


def test_supervisor_restart_event_and_checkpoint_timers(tmp_path, monkeypatch):
    from repro.checkpoint import CheckpointManager

    base, deltas = make_stream(seed=0)
    log = feed(SnapshotLog(V, capacity=512), base, deltas, WINDOW)
    view = WindowView(log, size=WINDOW)
    pending = deltas[WINDOW - 1:]

    sq = StreamingQuery(view, "sssp", 0, method="cqrs")
    calls = {"n": 0}
    orig = StreamingQuery.advance

    def chaos(self, delta=None):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("injected preemption")
        return orig(self, delta)

    monkeypatch.setattr(StreamingQuery, "advance", chaos)
    events = EventLog()
    reg = MetricsRegistry()
    with use_registry(reg):
        sup = ServeSupervisor(
            CheckpointManager(str(tmp_path)), ckpt_every=2, events=events
        )
        replica, served, stats = sup.run(sq, pending)
    assert stats["restarts"] == 1
    (ev,) = events.of_kind("restart")
    assert "injected preemption" in ev["cause"]
    assert ev["restore_slide"] <= ev["failed_slide"]
    assert ev["catchup_depth"] == ev["failed_slide"] - ev["restore_slide"]
    assert 0 <= ev["catchup_depth"] < sup.ckpt_every
    assert reg.counter("serving_restarts_total").value(worker="0") == 1
    # checkpoint wall-time histograms: initial save + periodic saves, one
    # restore, and the manager-level disk write/read timers underneath
    assert reg.histogram("checkpoint_save_seconds").snapshot()["count"] >= 2
    assert reg.histogram("checkpoint_restore_seconds").snapshot()["count"] == 1
    assert reg.histogram("checkpoint_write_seconds").snapshot()["count"] >= 2
    assert reg.histogram("checkpoint_read_seconds").snapshot()["count"] >= 1


# ===================================================================
# presence / packer mirrors
# ===================================================================
def test_presence_and_packer_counters_mirror_facades():
    view, pending = primed_view(seed=7)
    reg = MetricsRegistry()
    with use_registry(reg):
        sqb = StreamingQueryBatch(view, "sssp", [0, 7], method="cqrs_ell")
        sqb.results
        for d in pending:
            sqb.advance(d)
        touched = []
        rebuilds = 0
        for cache in sqb._presence.values():
            touched += cache.touched
            rebuilds += cache.rebuilds
    snap = snapshot(reg)
    assert snap["counters"]["presence_rebuilds_total"] == rebuilds
    assert snap["counters"].get("presence_touched_slots_total", 0) == \
        sum(touched)
    assert snap["counters"].get("presence_updates_total", 0) == len(touched)
    assert snap["counters"]["ell_repacks_total"] >= 1
    assert snap["counters"]["ell_class_transitions_total"] >= 1
    assert snap["gauges"]["ell_row_capacity"] >= 1


# ===================================================================
# BENCH json schema v2: the metrics block
# ===================================================================
def test_bench_payload_metrics_block_validates():
    from repro.utils.benchjson import make_payload, validate_bench_json

    metrics = {
        "counters": {"stream_slides_total": 6.0},
        "gauges": {"stream_uvv_fraction": 0.83},
        "per_slide": [{"slide": 0, "counters": {}}],
        "overhead": {"frac_of_p50": 0.001},
    }
    payload = make_payload(
        [("a", 1.0, "")], mode="fast", metrics=metrics
    )
    assert validate_bench_json(payload) is payload
    assert payload["metrics"] == metrics
    # omitted metrics block stays valid (schema v2 keeps it optional)
    validate_bench_json(make_payload([], mode="fast"))

    def bad(mutate):
        p = make_payload([], mode="fast", metrics=json.loads(
            json.dumps(metrics)
        ))
        mutate(p["metrics"])
        with pytest.raises(ValueError):
            validate_bench_json(p)

    bad(lambda m: m.pop("counters"))
    bad(lambda m: m.pop("gauges"))
    bad(lambda m: m["counters"].update(x="not-a-number"))
    bad(lambda m: m.update(per_slide="nope"))
    bad(lambda m: m.update(per_slide=[1, 2]))
    bad(lambda m: m.update(overhead={"frac": "high"}))

"""Per-architecture smoke tests: reduced config, one train/forward step on
CPU, output shapes + finiteness.  (Full configs are exercised only via the
dry-run — ShapeDtypeStruct, no allocation.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data.graphs import molecule_batch, random_graph_batch
from repro.data.recsys import recsys_batch
from repro.data.synthetic import synthetic_lm_batch
from repro.models.params import init_params
from repro.optim.adamw import AdamWConfig, adamw_init

LM_ARCHS = [a for a in list_archs() if get_arch(a).family == "lm"]
GNN_ARCHS = [a for a in list_archs() if get_arch(a).family == "gnn"]

OPT = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)


def _assert_finite(tree, msg=""):
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all()), f"non-finite {msg}"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import transformer_defs
    from repro.training.steps import build_lm_train_step

    cfg = get_arch(arch).smoke_config
    defs = transformer_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = synthetic_lm_batch(rng, 4, 32, cfg.vocab_size)
    step = jax.jit(build_lm_train_step(cfg, OPT))
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    _assert_finite(params, arch)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    from repro.models.transformer import cache_defs, decode_step, transformer_defs

    cfg = get_arch(arch).smoke_config
    params = init_params(transformer_defs(cfg), jax.random.PRNGKey(0))
    cache = init_params(cache_defs(cfg, 2, 16), jax.random.PRNGKey(1))
    logits, new_cache = jax.jit(
        lambda p, t, c, i: decode_step(cfg, p, t, c, i)
    )(params, jnp.array([1, 2], jnp.int32), cache, jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train_step(arch):
    from repro.models.gnn.dimenet import dimenet_defs
    from repro.models.gnn.equiformer_v2 import equiformer_defs
    from repro.models.gnn.gatedgcn import gatedgcn_defs
    from repro.models.gnn.pna import pna_defs
    from repro.training.steps import build_gnn_train_step

    cfg = get_arch(arch).smoke_config
    if cfg.arch == "dimenet":
        batch = molecule_batch(4, 8, 16, seed=0)
        batch.pop("num_graphs")
        ng = 4
    else:
        batch = random_graph_batch(96, 384, cfg.d_feat, cfg.num_classes, seed=0)
        ng = 1
    defs = {"pna": pna_defs, "gatedgcn": gatedgcn_defs, "dimenet": dimenet_defs,
            "equiformer_v2": equiformer_defs}[cfg.arch](cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(build_gnn_train_step(cfg, OPT, num_graphs=ng))
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    _assert_finite(params, arch)


def test_dlrm_smoke_train_step():
    from repro.models.dlrm import dlrm_defs
    from repro.training.steps import build_dlrm_train_step

    cfg = get_arch("dlrm-mlperf").smoke_config
    params = init_params(dlrm_defs(cfg), jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = recsys_batch(cfg, 16, seed=0)
    step = jax.jit(build_dlrm_train_step(cfg, OPT))
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    _assert_finite(params, "dlrm")


def test_evolving_smoke():
    from repro.core.api import evaluate_evolving_query
    from conftest import make_evolving

    cfg = get_arch("evolving-rmat").smoke_config
    eg = make_evolving(num_vertices=cfg.n_vertices, num_edges=cfg.n_edges,
                       num_snapshots=cfg.n_snapshots, batch_size=cfg.batch_updates)
    res, stats = evaluate_evolving_query(eg, cfg.query, cfg.source, "cqrs")
    assert res.shape == (cfg.n_snapshots, cfg.n_vertices)
    assert stats["frac_uvv"] > 0


def test_all_assigned_archs_registered():
    ids = list_archs(include_extra=False)
    assert sorted(ids) == sorted([
        "qwen2-moe-a2.7b", "deepseek-v2-236b", "stablelm-1.6b", "gemma-2b",
        "llama3-8b", "dimenet", "equiformer-v2", "pna", "gatedgcn",
        "dlrm-mlperf",
    ])
    # 40 assigned cells total
    assert sum(len(get_arch(a).shapes) for a in ids) == 40

"""GNN model smoke + invariance tests (reduced configs, CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.graphs import molecule_batch, random_graph_batch
from repro.models.gnn.common import GNNConfig, node_classification_loss
from repro.models.gnn.dimenet import dimenet_defs, dimenet_forward
from repro.models.gnn.equiformer_v2 import equiformer_defs, equiformer_forward
from repro.models.gnn.gatedgcn import gatedgcn_defs, gatedgcn_forward
from repro.models.gnn.pna import pna_defs, pna_forward
from repro.models.params import init_params


def _rotation(seed=0):
    rng = np.random.default_rng(seed)
    a, b, c = rng.uniform(0, 2 * np.pi, 3)
    rz = lambda t: np.array(
        [[np.cos(t), -np.sin(t), 0], [np.sin(t), np.cos(t), 0], [0, 0, 1]]
    )
    ry = lambda t: np.array(
        [[np.cos(t), 0, np.sin(t)], [0, 1, 0], [-np.sin(t), 0, np.cos(t)]]
    )
    return (rz(a) @ ry(b) @ rz(c)).astype(np.float32)


def test_pna_smoke():
    cfg = GNNConfig(name="pna-smoke", arch="pna", num_layers=2, d_hidden=32,
                    d_feat=24, num_classes=7)
    batch = random_graph_batch(60, 240, 24, 7, seed=0)
    params = init_params(pna_defs(cfg), jax.random.PRNGKey(0))
    logits = jax.jit(lambda p, b: pna_forward(cfg, p, b))(params, batch)
    assert logits.shape == (60, 7)
    assert bool(jnp.isfinite(logits).all())
    loss = node_classification_loss(logits, batch["labels"])
    g = jax.grad(lambda p: node_classification_loss(pna_forward(cfg, p, batch), batch["labels"]))(params)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree_util.tree_leaves(g))


def test_gatedgcn_smoke():
    cfg = GNNConfig(name="ggcn-smoke", arch="gatedgcn", num_layers=3, d_hidden=24,
                    d_feat=24, num_classes=5, d_edge_feat=8)
    batch = random_graph_batch(50, 200, 24, 5, seed=1)
    params = init_params(gatedgcn_defs(cfg), jax.random.PRNGKey(1))
    logits = jax.jit(lambda p, b: gatedgcn_forward(cfg, p, b))(params, batch)
    assert logits.shape == (50, 5)
    assert bool(jnp.isfinite(logits).all())


def test_dimenet_smoke_and_invariance():
    cfg = GNNConfig(name="dimenet-smoke", arch="dimenet", num_layers=2, d_hidden=32,
                    d_feat=16, num_classes=1, n_radial=6, n_spherical=7, n_bilinear=8)
    batch = molecule_batch(4, 8, 16, seed=2)
    batch.pop("num_graphs")
    params = init_params(dimenet_defs(cfg), jax.random.PRNGKey(2))
    fwd = jax.jit(lambda p, b: dimenet_forward(cfg, p, b, num_graphs=4))
    e = fwd(params, batch)
    assert e.shape == (4,)
    assert bool(jnp.isfinite(e).all())
    # rotation + translation invariance of predicted energies
    r = _rotation(3)
    batch_rot = dict(batch)
    batch_rot["pos"] = batch["pos"] @ r.T + jnp.asarray([1.0, -2.0, 0.5])
    e_rot = fwd(params, batch_rot)
    np.testing.assert_allclose(np.asarray(e), np.asarray(e_rot), rtol=2e-4, atol=2e-4)


def test_equiformer_smoke_and_invariance():
    cfg = GNNConfig(name="eqv2-smoke", arch="equiformer_v2", num_layers=2,
                    d_hidden=16, d_feat=12, num_classes=4, l_max=3, m_max=2,
                    num_heads=4)
    batch = random_graph_batch(40, 160, 12, 4, seed=3, with_pos=True)
    params = init_params(equiformer_defs(cfg), jax.random.PRNGKey(3))
    fwd = jax.jit(lambda p, b: equiformer_forward(cfg, p, b))
    logits = fwd(params, batch)
    assert logits.shape == (40, 4)
    assert bool(jnp.isfinite(logits).all())
    # invariant (l=0) readout → logits unchanged under global rotation
    r = _rotation(4)
    batch_rot = dict(batch)
    batch_rot["pos"] = batch["pos"] @ r.T
    logits_rot = fwd(params, batch_rot)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(logits_rot), rtol=5e-3, atol=5e-3
    )


def test_equiformer_edge_chunking_equivalent():
    """Chunked (custom-VJP recompute) path == dense path, values AND grads."""
    import dataclasses

    cfg = GNNConfig(name="eqv2-chunk", arch="equiformer_v2", num_layers=1,
                    d_hidden=16, d_feat=12, num_classes=4, l_max=2, m_max=1,
                    num_heads=2)
    batch = random_graph_batch(30, 128, 12, 4, seed=5, with_pos=True)
    params = init_params(equiformer_defs(cfg), jax.random.PRNGKey(5))
    cfg_chunked = dataclasses.replace(cfg, edge_chunk=32)

    def loss(c, p):
        return jnp.sum(equiformer_forward(c, p, batch) ** 2)

    full, g_full = jax.value_and_grad(lambda p: loss(cfg, p))(params)
    chunked, g_chunk = jax.value_and_grad(lambda p: loss(cfg_chunked, p))(params)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), rtol=2e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_full), jax.tree_util.tree_leaves(g_chunk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)


def test_dimenet_triplet_chunking_equivalent():
    import dataclasses

    cfg = GNNConfig(name="dn-chunk", arch="dimenet", num_layers=2, d_hidden=16,
                    d_feat=16, num_classes=1)
    batch = molecule_batch(4, 8, 16, seed=7)
    batch.pop("num_graphs")
    t = int(batch["triplet_kj"].shape[0])
    pad = (-t) % 16
    for k in ("triplet_kj", "triplet_ji"):
        batch[k] = jnp.pad(batch[k], (0, pad))
    batch["triplet_valid"] = jnp.pad(batch["triplet_valid"], (0, pad))
    params = init_params(dimenet_defs(cfg), jax.random.PRNGKey(7))
    cfg_chunked = dataclasses.replace(cfg, triplet_chunk=16)

    def loss(c, p):
        return jnp.sum(dimenet_forward(c, p, batch, num_graphs=4) ** 2)

    full, g_full = jax.value_and_grad(lambda p: loss(cfg, p))(params)
    chunked, g_chunk = jax.value_and_grad(lambda p: loss(cfg_chunked, p))(params)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), rtol=2e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_full), jax.tree_util.tree_leaves(g_chunk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)

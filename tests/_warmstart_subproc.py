"""Subprocess halves of the restarted-process compile-stability check.

Run as ``python _warmstart_subproc.py <phase> <cache_dir>``:

* ``warm`` — the pre-crash process: serves the stream once while probing the
  kernel grid (``grid_for`` after prime and after every slide), then runs
  :func:`repro.serving.warmstart.warmup` against a persistent executable
  cache directory.  Everything the serving path will ever compile lands on
  disk, plus the ``grid.json`` manifest.
* ``serve`` — the restarted process: replays the manifest
  (:func:`warm_from_manifest`), then builds the SAME replica and serves the
  SAME stream, asserting that (a) the executable cache directory gains ZERO
  new files from the moment the manifest replay finished — every XLA
  compile, including the vmapped dispatch paths, is a disk hit — and (b)
  the module-level jit cache-miss counters are frozen across the served
  slides.  Prints ``CHECK_OK`` on success (the pytest wrapper greps for it).

Prints ``SKIP`` when this JAX build lacks the persistent-cache knobs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np  # noqa: E402

V = 48
WINDOW = 3
SOURCES = [0, 7, 13, 21]


def build(seed: int = 0):
    from repro.core.api import StreamingQueryBatch
    from repro.graph.generators import (
        generate_evolving_stream,
        generate_rmat,
        generate_uniform_weights,
    )
    from repro.graph.stream import SnapshotLog, WindowView

    src, dst = generate_rmat(V, 192, seed=seed)
    w = generate_uniform_weights(len(src), seed=seed + 1, grid=16)
    base, deltas = generate_evolving_stream(
        src, dst, w, V, num_snapshots=WINDOW + 4, batch_size=20,
        readd_prob=0.4, seed=seed + 2,
    )
    log = SnapshotLog(V, capacity=512)
    log.append_snapshot(*base)
    for d in deltas[: WINDOW - 1]:
        log.append_snapshot(*d)
    view = WindowView(log, size=WINDOW)
    sq = StreamingQueryBatch(view, "sssp", SOURCES, method="cqrs_ell")
    return sq, deltas[WINDOW - 1:]


def _counters():
    from repro.core.concurrent import concurrent_fixpoint_batch
    from repro.core.engine import (
        compute_fixpoint,
        compute_parents,
        incremental_fixpoint,
        invalidate_from_deletions,
    )
    from repro.kernels.vrelax.ops import (
        concurrent_fixpoint_ell,
        concurrent_fixpoint_ell_batch,
    )

    return [
        fn for fn in (
            compute_fixpoint, incremental_fixpoint, compute_parents,
            invalidate_from_deletions, concurrent_fixpoint_batch,
            concurrent_fixpoint_ell, concurrent_fixpoint_ell_batch,
        )
        if hasattr(fn, "_cache_size")
    ]


def _listing(cache_dir):
    return sorted(
        os.path.relpath(os.path.join(r, f), cache_dir)
        for r, _, fs in os.walk(cache_dir) for f in fs
    )


def phase_warm(cache_dir):
    from repro.serving.warmstart import (
        enable_persistent_cache, grid_for, warmup,
    )

    if not enable_persistent_cache(cache_dir):
        print("SKIP: persistent compilation cache unsupported")
        return
    sq, pending = build()
    sq.results
    specs, seen = [], set()

    def probe():
        s = grid_for(sq)
        if s.key() not in seen:
            seen.add(s.key())
            specs.append(s)

    probe()
    for d in pending:
        sq.advance(d)
        probe()
    report = warmup(specs, cache_dir=cache_dir)
    assert os.path.exists(report["manifest"])
    n_exec = len(_listing(cache_dir))
    assert n_exec > 1, "persistent cache captured no executables"
    print(f"WARM_OK specs={len(report['specs'])} cached={n_exec}")


def phase_serve(cache_dir):
    from repro.serving.warmstart import (
        enable_persistent_cache, warm_from_manifest,
    )

    if not enable_persistent_cache(cache_dir):
        print("SKIP: persistent compilation cache unsupported")
        return
    report = warm_from_manifest(cache_dir)
    assert report["specs"], "manifest replay warmed nothing"
    on_disk = _listing(cache_dir)
    sq, pending = build()
    sq.results  # prime: cold solve — every compile must be a disk hit
    fns = _counters()
    misses = [fn._cache_size() for fn in fns]
    for d in pending:
        sq.advance(d)
    assert [fn._cache_size() for fn in fns] == misses, \
        "serving path traced new kernel variants after manifest replay"
    new = sorted(set(_listing(cache_dir)) - set(on_disk))
    assert not new, (
        f"restarted process compiled {len(new)} new executables on the "
        f"serving path: {new[:4]}"
    )
    print(f"CHECK_OK served={len(pending)} cached={len(on_disk)}")


if __name__ == "__main__":
    {"warm": phase_warm, "serve": phase_serve}[sys.argv[1]](sys.argv[2])

"""Unit coverage: padding, presence bitmasks, token pipeline, HLO parser,
ELL packing, sampler, schedules."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.data.synthetic import TokenPipeline
from repro.graph.ell import pack_ell
from repro.graph.sampler import NeighborSampler
from repro.graph.structures import CSR, pack_presence, unpack_presence
from repro.roofline.analysis import collective_bytes_from_hlo
from repro.utils.padding import pad_to, pad_to_multiple, round_up


# ---------------------------------------------------------------- padding
def test_round_up():
    assert round_up(1, 128) == 128
    assert round_up(128, 128) == 128
    assert round_up(129, 128) == 256
    with pytest.raises(ValueError):
        round_up(5, 0)


def test_pad_to_rejects_shrink():
    with pytest.raises(ValueError):
        pad_to(np.zeros(10), 5, 0)


# ---------------------------------------------------------------- presence
@settings(max_examples=20, deadline=None)
@given(s=st.integers(1, 70), e=st.integers(1, 50), seed=st.integers(0, 1000))
def test_presence_pack_unpack_roundtrip(s, e, seed):
    rng = np.random.default_rng(seed)
    dense = rng.random((s, e)) < 0.5
    packed = pack_presence(dense)
    assert packed.shape == (e, (s + 31) // 32)
    back = np.asarray(unpack_presence(jnp.asarray(packed), s))
    np.testing.assert_array_equal(back, dense)


# ---------------------------------------------------------------- pipeline
def test_token_pipeline_deterministic_restart():
    p1 = TokenPipeline(batch=8, seq=16, vocab=100, seed=3)
    batches = [p1.next() for _ in range(5)]
    state = p1.state()
    after = [p1.next() for _ in range(3)]
    p2 = TokenPipeline(batch=8, seq=16, vocab=100, seed=0)
    p2.restore(state)
    replay = [p2.next() for _ in range(3)]
    for a, b in zip(after, replay):
        np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_token_pipeline_shards_disjoint_content():
    a = TokenPipeline(batch=8, seq=16, vocab=1000, shard_id=0, num_shards=2).next()
    b = TokenPipeline(batch=8, seq=16, vocab=1000, shard_id=1, num_shards=2).next()
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


# ---------------------------------------------------------------- roofline
def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %all-gather.1 = f32[64,128]{1,0} all-gather(%p0), replica_groups=[4,2]<=[8]
  %all-reduce.2 = bf16[32]{0} all-reduce(%p1), replica_groups=[8,1]<=[8]
  %rs = f32[16,8]{1,0} reduce-scatter(%p2), replica_groups=[4,2]<=[8], dimensions={0}
  %ar-start = f32[10]{0} all-reduce-start(%p3), replica_groups=[2,4]<=[8]
  %ar-done = f32[10]{0} all-reduce-done(%ar-start)
  %noise = f32[100]{0} add(%a, %b)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 64 * 128 * 4
    assert out["all-reduce"] == 32 * 2 + 10 * 4  # bf16 + the -start (done skipped)
    assert out["reduce-scatter"] == 16 * 8 * 4 * 4  # scaled by group size 4
    assert out["counts"]["all-reduce"] == 2


# ---------------------------------------------------------------- ELL
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), v=st.integers(2, 40), e=st.integers(1, 120))
def test_ell_pack_preserves_edges(seed, v, e):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    w = rng.random(e).astype(np.float32)
    ell = pack_ell(src, dst, w, v, slot_width=8, row_align=4)
    # every (src, dst, w) triple appears exactly once in the packing
    got = []
    sv = np.asarray(ell.slot_valid)
    es = np.asarray(ell.src)
    ew = np.asarray(ell.weight)
    r2v = np.asarray(ell.row2vertex)
    for r in range(ell.num_rows):
        for c in range(8):
            if sv[r, c]:
                got.append((es[r, c], r2v[r], ew[r, c]))
    want = sorted(zip(src.tolist(), dst.tolist(), w.tolist()))
    assert sorted(got) == [(int(s), int(d), float(x)) for s, d, x in want]


# ---------------------------------------------------------------- sampler
def test_sampler_respects_adjacency():
    rng = np.random.default_rng(0)
    v, e = 50, 400
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    csr = CSR.from_edges(src, dst, np.ones(e, np.float32), v)
    sampler = NeighborSampler(csr, fanouts=(5,))
    seeds = jnp.arange(10, dtype=jnp.int32)
    blocks = sampler.sample(jax.random.PRNGKey(0), seeds)
    adj = {}
    for s, d in zip(src.tolist(), dst.tolist()):
        adj.setdefault(s, set()).add(d)
    nbrs = np.asarray(blocks.neighbors[0]).reshape(10, 5)
    valid = np.asarray(blocks.valid[0]).reshape(10, 5)
    for i, seed in enumerate(range(10)):
        for j in range(5):
            if valid[i, j]:
                assert int(nbrs[i, j]) in adj.get(seed, set())
            else:
                assert seed not in adj  # degree-0 seeds only


# ---------------------------------------------------------------- schedules
def test_lr_monotone_phases():
    from repro.optim.schedules import warmup_cosine

    xs = [float(warmup_cosine(s, peak_lr=2.0, warmup_steps=5, total_steps=50))
          for s in range(50)]
    assert all(b >= a for a, b in zip(xs[:5], xs[1:6]))  # warmup rises
    assert all(b <= a + 1e-9 for a, b in zip(xs[5:-1], xs[6:]))  # cosine falls

"""Property-testing shim: real hypothesis when installed, seed-sweep otherwise.

The tier-1 suite must collect and pass in a clean environment (``hypothesis``
is an optional extra — ``pip install .[fuzz]`` — not a hard test dependency).
When the package is present we re-export the genuine ``given`` / ``settings``
/ ``strategies`` so shrinking and example databases work as usual.  When it is
absent, the fallback replays each property over a *fixed* deterministic sweep
of examples: every ``@given`` strategy draws from a ``random.Random`` seeded
per example index, so a clean-environment run is reproducible and a failure
message names the exact drawn values.

Only the strategy surface the suite actually uses is shimmed
(``st.integers``, ``st.sampled_from``); extend here before reaching for a new
strategy in a test.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Integers:
        def __init__(self, min_value, max_value):
            self.min_value = int(min_value)
            self.max_value = int(max_value)

        def sample(self, rng: "random.Random"):
            return rng.randint(self.min_value, self.max_value)

    class _SampledFrom:
        def __init__(self, elements):
            self.elements = list(elements)

        def sample(self, rng: "random.Random"):
            return self.elements[rng.randrange(len(self.elements))]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    st = _Strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        """Accepts (and mostly ignores) hypothesis settings kwargs."""

        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_prop_max_examples", _DEFAULT_MAX_EXAMPLES)
                for case in range(n):
                    rng = random.Random((0x5EED << 20) ^ case)
                    drawn = {
                        name: strat.sample(rng)
                        for name, strat in sorted(strategies.items())
                    }
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"seed-sweep example {case}/{n} failed with {drawn!r}"
                        ) from exc

            # hide the strategy parameters from pytest's fixture resolution:
            # they are drawn by the sweep, not injected as fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
